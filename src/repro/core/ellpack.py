"""Incremental ELLPACK relaxation backend for the dynamic engine.

The segment backend (core/relax.py) scatter-reduces over the flat COO edge
pool; this module keeps a second, TPU-native view of the same graph — a
by-destination ELLPACK block ``(nbr_idx, nbr_w)`` of shape (R, K) — and
maintains it *incrementally* under ADD/DEL batches (DESIGN.md §2):

  * ADD  — the host planner assigns each new edge a (row, k) cell past the
    row's fill high-water mark; the device patch is one idempotent scatter.
  * DEL  — resolved entirely on device: each deleted edge's cell is found by
    matching the source id in its destination row and tombstoned (w := +inf).
    No host map of ELL positions exists at all.
  * weight-decrease (``on_duplicate="min"``) — device-side match + min-scatter.
  * overflow — when a row's fill would exceed K, the planner rebuilds the
    whole block from the host COO mirror with K doubled (next pow2 of twice
    the max in-degree) and tombstones compacted away.  O(E) numpy + one
    transfer, amortized over the doublings.

All patch ops are jitted, tolerate pad_pow2-repeated rows (their scatters are
idempotent or min/max-combined), and never read device memory back.

Epoch functions mirror core/relax.py and core/delete.py exactly — same
frontier evolution, same smallest-src-id tie-break — so (dist, parent) are
bit-identical between the two backends (test_backend_equiv.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delete as del_mod
from repro.core.relax import RelaxStats
from repro.core.state import INF, NO_PARENT, SSSPState
from repro.graphs import csr as csr_mod
from repro.kernels.relax.ops import relax_wave

_NEG_INF = jnp.float32(-jnp.inf)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllState:
    """Device-resident sliced-ELL view of the active edge set.

    ``fill`` is each row's occupancy high-water mark: cells at k >= fill[r]
    have never been written; cells below it are live edges or tombstones
    (w == +inf).  Rows n..R-1 are kernel block padding and stay empty.
    """

    nbr_idx: jax.Array  # i32[R, K] in-neighbor ids (0 where empty/tombstone)
    nbr_w: jax.Array    # f32[R, K] weights (+inf where empty/tombstone)
    fill: jax.Array     # i32[R]

    @property
    def k(self) -> int:
        return self.nbr_w.shape[1]

    @property
    def rows(self) -> int:
        return self.nbr_w.shape[0]


# --------------------------------------------------------------- patch ops --
@jax.jit
def ell_append(ell: EllState, rows: jax.Array, kpos: jax.Array,
               src: jax.Array, w: jax.Array) -> EllState:
    """Write fresh edges into planner-assigned cells (idempotent scatter —
    pad_pow2 repeats of the same (row, kpos, src, w) are no-ops)."""
    return EllState(
        nbr_idx=ell.nbr_idx.at[rows, kpos].set(src),
        nbr_w=ell.nbr_w.at[rows, kpos].set(w),
        fill=ell.fill.at[rows].max(kpos + 1),
    )


def _match_cell(ell: EllState, rows: jax.Array, src: jax.Array):
    """Locate each (src -> rows) edge's live cell: (kpos, found).

    Live edges are unique per (row, src) — the slot allocator dedups — so at
    most one finite-weight cell matches.
    """
    row_idx = ell.nbr_idx[rows]                      # (m, K)
    row_w = ell.nbr_w[rows]                          # (m, K)
    hit = (row_idx == src[:, None]) & jnp.isfinite(row_w)
    return jnp.argmax(hit, axis=1), jnp.any(hit, axis=1)


@jax.jit
def ell_delete(ell: EllState, rows: jax.Array, src: jax.Array) -> EllState:
    """Tombstone deleted edges (w := +inf), located on device by source-id
    match.  Duplicate (row, src) pairs from batch padding collapse to the
    same cell; the max-combine makes the scatter order-free."""
    kpos, found = _match_cell(ell, rows, src)
    val = jnp.where(found, INF, _NEG_INF)            # -inf = no-op under max
    return dataclasses.replace(
        ell, nbr_w=ell.nbr_w.at[rows, kpos].max(val))


@jax.jit
def ell_update_min(ell: EllState, rows: jax.Array, src: jax.Array,
                   w: jax.Array) -> EllState:
    """Weight-decrease of existing edges (on_duplicate="min"): device-side
    match + min-scatter (+inf = no-op for unmatched/padded entries)."""
    kpos, found = _match_cell(ell, rows, src)
    val = jnp.where(found, w, INF)
    return dataclasses.replace(
        ell, nbr_w=ell.nbr_w.at[rows, kpos].min(val))


@jax.jit
def ell_invariants(ell: EllState) -> dict[str, jax.Array]:
    """Occupancy invariants over the device fill marks (diagnostics/tests):
    every cell at or past a row's fill mark must be empty (+inf), and fill
    must stay within the block width.  Guards the device copy of the fill
    state against drifting from the host planner's."""
    k_iota = jax.lax.broadcasted_iota(jnp.int32, ell.nbr_w.shape, 1)
    beyond = k_iota >= ell.fill[:, None]
    return {
        "beyond_fill_empty": jnp.all(jnp.where(beyond, jnp.isinf(ell.nbr_w),
                                               True)),
        "fill_in_range": jnp.all((ell.fill >= 0)
                                 & (ell.fill <= ell.nbr_w.shape[1])),
    }


# ------------------------------------------------------------ host planner --
def _next_pow2(x: int) -> int:
    m = 1
    while m < x:
        m <<= 1
    return m


class EllPlanner:
    """Host control plane for the ELL block: assigns append cells, detects
    overflow, and rebuilds (with capacity doubling) from the host COO mirror.

    Keeps only dense per-row fill counts — deletions and weight updates are
    resolved on device, so there is no host map of ELL cell positions.
    """

    def __init__(self, num_vertices: int, *, block_rows: int = 256,
                 init_k: int = 8):
        self.n = num_vertices
        bm = min(block_rows, _next_pow2(max(num_vertices, 1)))
        self.rows = -(-num_vertices // bm) * bm      # ceil to block multiple
        self.k = max(1, init_k)
        self.fill = np.zeros(self.rows, np.int32)
        self.rebuilds = 0

    def empty_state(self) -> EllState:
        return EllState(
            nbr_idx=jnp.zeros((self.rows, self.k), jnp.int32),
            nbr_w=jnp.full((self.rows, self.k), INF, jnp.float32),
            fill=jnp.zeros((self.rows,), jnp.int32),
        )

    def plan_appends(self, rows: np.ndarray) -> np.ndarray | None:
        """Assign a distinct cell past the fill mark to each fresh edge.

        Returns kpos i32[m] (and advances the fill marks), or None when any
        row would overflow K — the caller must rebuild instead.
        """
        m = len(rows)
        if m == 0:
            return np.empty(0, np.int32)
        counts = np.bincount(rows, minlength=self.n)
        if int((self.fill[:self.n] + counts[:self.n]).max(initial=0)) > self.k:
            return None
        order = np.argsort(rows, kind="stable")
        sr = rows[order]
        starts = np.nonzero(np.r_[True, sr[1:] != sr[:-1]])[0]
        sizes = np.diff(np.r_[starts, m])
        rank = np.empty(m, np.int64)
        rank[order] = np.arange(m) - np.repeat(starts, sizes)
        kpos = self.fill[rows] + rank
        np.maximum.at(self.fill, rows, kpos + 1)
        return kpos.astype(np.int32)

    def rebuild(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                ) -> EllState:
        """Rebuild the device block from the live COO edge set (host mirror):
        compacts tombstones and doubles K to the next pow2 of 2x the max
        in-degree when the degree itself (not churn) caused the overflow."""
        deg = np.bincount(dst, minlength=self.n) if len(dst) else \
            np.zeros(self.n, np.int64)
        needed = int(deg.max(initial=0))
        self.k = max(self.k, _next_pow2(max(2 * needed, 1)))
        idx, ww, fill = csr_mod.ell_from_coo(
            self.n, src, dst, w, k=self.k, n_rows=self.rows)
        self.fill = fill
        self.rebuilds += 1
        return EllState(nbr_idx=jnp.asarray(idx), nbr_w=jnp.asarray(ww),
                        fill=jnp.asarray(fill))


# ------------------------------------------------------------------ epochs --
@partial(jax.jit, static_argnames=("num_vertices", "max_rounds",
                                   "use_kernel", "interpret"))
def ell_relax_until_converged(
    sssp: SSSPState,
    nbr_idx: jax.Array,
    nbr_w: jax.Array,
    frontier: jax.Array,
    *,
    num_vertices: int,
    max_rounds: int = 0,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[SSSPState, RelaxStats]:
    """ELL rendering of relax.relax_until_converged: frontier-masked waves to
    fixpoint.  Same candidate sets, same tie-break => bit-identical results."""

    def cond(carry):
        _, _, frontier, rounds, _ = carry
        go = jnp.any(frontier)
        if max_rounds:
            go = go & (rounds < max_rounds)
        return go

    def body(carry):
        dist, parent, frontier, rounds, msgs = carry
        dist, parent, improved = relax_wave(
            dist, parent, nbr_idx, nbr_w, frontier=frontier,
            use_kernel=use_kernel, interpret=interpret)
        return (dist, parent, improved, rounds + 1,
                msgs + jnp.sum(improved.astype(jnp.int32)))

    dist, parent, _, rounds, msgs = jax.lax.while_loop(
        cond, body,
        (sssp.dist, sssp.parent, frontier, jnp.int32(0), jnp.int32(0)),
    )
    return (
        SSSPState(dist=dist, parent=parent, source=sssp.source),
        RelaxStats(rounds=rounds, messages=msgs),
    )


@partial(jax.jit, static_argnames=("num_vertices", "use_doubling",
                                   "use_kernel", "interpret"))
def ell_invalidate_and_recompute(
    sssp: SSSPState,
    nbr_idx: jax.Array,
    nbr_w: jax.Array,
    seed: jax.Array,
    *,
    num_vertices: int,
    use_doubling: bool = True,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[SSSPState, del_mod.DeleteStats]:
    """Deletion epoch on the ELL block (paper Listings 4/8/9).

    Invalidation reuses the parent-forest marking from core/delete.py (it
    does not touch edges).  The bulk DistanceQuery pull is ONE ELL wave: every
    affected row gathers offers from all in-neighbors at once (+inf sources —
    other affected vertices — and tombstones offer nothing), then ordinary
    frontier-masked waves drain the epoch.

    Safe to call with an all-false seed (non-tree deletions): the state is
    returned unchanged and every stat is 0, which lets the engine skip the
    blocking ``bool(jnp.any(seed))`` host sync entirely (DESIGN.md §2.4).
    """
    any_seed = jnp.any(seed)
    mark = (del_mod.mark_subtree_doubling if use_doubling
            else del_mod.mark_subtree_flood)
    aff, inv_rounds = mark(sssp.parent, seed)
    aff = aff.at[sssp.source].set(False)

    dist = jnp.where(aff, INF, sssp.dist)
    parent = jnp.where(aff, NO_PARENT, sssp.parent)

    # Bulk pull: one unmasked wave, improvements applied to affected rows
    # only (matching the segment path's ``aff[dst]`` edge mask; unaffected
    # rows cannot improve anyway — the pre-deletion state was converged).
    dist_p, parent_p, improved = relax_wave(
        dist, parent, nbr_idx, nbr_w,
        use_kernel=use_kernel, interpret=interpret)
    improved = improved & aff
    dist = jnp.where(improved, dist_p, dist)
    parent = jnp.where(improved, parent_p, parent)

    state1 = SSSPState(dist=dist, parent=parent, source=sssp.source)
    state2, stats = ell_relax_until_converged(
        state1, nbr_idx, nbr_w, improved, num_vertices=num_vertices,
        use_kernel=use_kernel, interpret=interpret)
    zero = jnp.int32(0)
    return state2, del_mod.DeleteStats(
        invalidation_rounds=jnp.where(any_seed, inv_rounds, zero),
        affected=jnp.sum(aff.astype(jnp.int32)),
        recompute_rounds=jnp.where(any_seed, stats.rounds + 1, zero),
        recompute_messages=jnp.where(
            any_seed,
            stats.messages + jnp.sum(improved.astype(jnp.int32)), zero),
    )
