"""Incremental ELLPACK relaxation backends for the dynamic engine.

The segment backend (core/relax.py) scatter-reduces over the flat COO edge
pool; this module keeps a second, TPU-native view of the same graph and
maintains it *incrementally* under ADD/DEL batches.  Two layouts:

  * ``EllState`` — the dense by-destination ELLPACK block ``(nbr_idx,
    nbr_w)`` of shape (R, K), one global K (DESIGN.md §2);
  * ``SlicedEllState`` — the hub-aware hybrid (DESIGN.md §6): rows bucketed
    into degree slices with per-slice pow2 K (capped at a hub threshold),
    flattened into one 1-D cell buffer, plus a device COO *overflow* segment
    holding hub rows' surplus in-edges, relaxed with the segment-min kernel
    and min-combined with the per-slice ELL waves.

Dense-ELL maintenance (the sliced ops mirror it cell-for-cell):

  * ADD  — the host planner assigns each new edge a (row, k) cell past the
    row's fill high-water mark; the device patch is one idempotent scatter.
  * DEL  — resolved entirely on device: each deleted edge's cell is found by
    matching the source id in its destination row and tombstoned (w := +inf).
    No host map of ELL positions exists at all.
  * weight-decrease (``on_duplicate="min"``) — device-side match + min-scatter.
  * overflow — when a row's fill would exceed K, the planner rebuilds the
    whole block from the host COO mirror with K doubled (next pow2 of twice
    the max in-degree) and tombstones compacted away.  O(E) numpy + one
    transfer, amortized over the doublings.

All patch ops are jitted, tolerate pad_pow2-repeated rows (their scatters are
idempotent or min/max-combined), and never read device memory back.

Epoch functions mirror core/relax.py and core/delete.py exactly — same
frontier evolution, same smallest-src-id tie-break — so (dist, parent) are
bit-identical between the two backends (test_backend_equiv.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delete as del_mod
from repro.core.relax import RelaxStats
from repro.core.state import INF, NO_PARENT, SSSPState
from repro.graphs import csr as csr_mod
from repro.kernels.relax.ops import relax_wave

_NEG_INF = jnp.float32(-jnp.inf)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllState:
    """Device-resident dense-ELL view of the active edge set (one global K;
    the hub-aware sliced/hybrid variant is ``SlicedEllState`` below).

    ``fill`` is each row's occupancy high-water mark: cells at k >= fill[r]
    have never been written; cells below it are live edges or tombstones
    (w == +inf).  Rows n..R-1 are kernel block padding and stay empty.
    """

    nbr_idx: jax.Array  # i32[R, K] in-neighbor ids (0 where empty/tombstone)
    nbr_w: jax.Array    # f32[R, K] weights (+inf where empty/tombstone)
    fill: jax.Array     # i32[R]

    @property
    def k(self) -> int:
        return self.nbr_w.shape[1]

    @property
    def rows(self) -> int:
        return self.nbr_w.shape[0]


# --------------------------------------------------------------- patch ops --
@jax.jit
def ell_append(ell: EllState, rows: jax.Array, kpos: jax.Array,
               src: jax.Array, w: jax.Array) -> EllState:
    """Write fresh edges into planner-assigned cells (idempotent scatter —
    pad_pow2 repeats of the same (row, kpos, src, w) are no-ops)."""
    return EllState(
        nbr_idx=ell.nbr_idx.at[rows, kpos].set(src),
        nbr_w=ell.nbr_w.at[rows, kpos].set(w),
        fill=ell.fill.at[rows].max(kpos + 1),
    )


def _match_cell(ell: EllState, rows: jax.Array, src: jax.Array):
    """Locate each (src -> rows) edge's live cell: (kpos, found).

    Live edges are unique per (row, src) — the slot allocator dedups — so at
    most one finite-weight cell matches.
    """
    row_idx = ell.nbr_idx[rows]                      # (m, K)
    row_w = ell.nbr_w[rows]                          # (m, K)
    hit = (row_idx == src[:, None]) & jnp.isfinite(row_w)
    return jnp.argmax(hit, axis=1), jnp.any(hit, axis=1)


@jax.jit
def ell_delete(ell: EllState, rows: jax.Array, src: jax.Array) -> EllState:
    """Tombstone deleted edges (w := +inf), located on device by source-id
    match.  Duplicate (row, src) pairs from batch padding collapse to the
    same cell; the max-combine makes the scatter order-free."""
    kpos, found = _match_cell(ell, rows, src)
    val = jnp.where(found, INF, _NEG_INF)            # -inf = no-op under max
    return dataclasses.replace(
        ell, nbr_w=ell.nbr_w.at[rows, kpos].max(val))


@jax.jit
def ell_update_min(ell: EllState, rows: jax.Array, src: jax.Array,
                   w: jax.Array) -> EllState:
    """Weight-decrease of existing edges (on_duplicate="min"): device-side
    match + min-scatter (+inf = no-op for unmatched/padded entries)."""
    kpos, found = _match_cell(ell, rows, src)
    val = jnp.where(found, w, INF)
    return dataclasses.replace(
        ell, nbr_w=ell.nbr_w.at[rows, kpos].min(val))


@jax.jit
def ell_invariants(ell: EllState) -> dict[str, jax.Array]:
    """Occupancy invariants over the device fill marks (diagnostics/tests):
    every cell at or past a row's fill mark must be empty (+inf), and fill
    must stay within the block width.  Guards the device copy of the fill
    state against drifting from the host planner's."""
    k_iota = jax.lax.broadcasted_iota(jnp.int32, ell.nbr_w.shape, 1)
    beyond = k_iota >= ell.fill[:, None]
    return {
        "beyond_fill_empty": jnp.all(jnp.where(beyond, jnp.isinf(ell.nbr_w),
                                               True)),
        "fill_in_range": jnp.all((ell.fill >= 0)
                                 & (ell.fill <= ell.nbr_w.shape[1])),
    }


# ------------------------------------------------------------ host planner --
_next_pow2 = csr_mod.next_pow2


def _rank_within_rows(rows: np.ndarray) -> np.ndarray:
    """Rank of each batch entry among the entries targeting the same row,
    in stable batch order — the cell-offset assignment both planners use
    (kpos candidate = fill[row] + rank)."""
    m = len(rows)
    order = np.argsort(rows, kind="stable")
    sr = rows[order]
    starts = np.nonzero(np.r_[True, sr[1:] != sr[:-1]])[0]
    sizes = np.diff(np.r_[starts, m])
    rank = np.empty(m, np.int64)
    rank[order] = np.arange(m) - np.repeat(starts, sizes)
    return rank


class EllPlanner:
    """Host control plane for the ELL block: assigns append cells, detects
    overflow, and rebuilds (with capacity doubling) from the host COO mirror.

    Keeps only dense per-row fill counts — deletions and weight updates are
    resolved on device, so there is no host map of ELL cell positions.
    """

    def __init__(self, num_vertices: int, *, block_rows: int = 256,
                 init_k: int = 8):
        self.n = num_vertices
        bm = min(block_rows, _next_pow2(max(num_vertices, 1)))
        self.rows = -(-num_vertices // bm) * bm      # ceil to block multiple
        self.k = max(1, init_k)
        self.fill = np.zeros(self.rows, np.int32)
        self.rebuilds = 0

    def empty_state(self) -> EllState:
        return EllState(
            nbr_idx=jnp.zeros((self.rows, self.k), jnp.int32),
            nbr_w=jnp.full((self.rows, self.k), INF, jnp.float32),
            fill=jnp.zeros((self.rows,), jnp.int32),
        )

    def plan_appends(self, rows: np.ndarray) -> np.ndarray | None:
        """Assign a distinct cell past the fill mark to each fresh edge.

        Returns kpos i32[m] (and advances the fill marks), or None when any
        row would overflow K — the caller must rebuild instead.
        """
        m = len(rows)
        if m == 0:
            return np.empty(0, np.int32)
        counts = np.bincount(rows, minlength=self.n)
        if int((self.fill[:self.n] + counts[:self.n]).max(initial=0)) > self.k:
            return None
        kpos = self.fill[rows] + _rank_within_rows(rows)
        np.maximum.at(self.fill, rows, kpos + 1)
        return kpos.astype(np.int32)

    def rebuild(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                ) -> EllState:
        """Rebuild the device block from the live COO edge set (host mirror):
        compacts tombstones and doubles K to the next pow2 of 2x the max
        in-degree when the degree itself (not churn) caused the overflow."""
        deg = np.bincount(dst, minlength=self.n) if len(dst) else \
            np.zeros(self.n, np.int64)
        needed = int(deg.max(initial=0))
        self.k = max(self.k, _next_pow2(max(2 * needed, 1)))
        idx, ww, fill = csr_mod.ell_from_coo(
            self.n, src, dst, w, k=self.k, n_rows=self.rows)
        self.fill = fill
        self.rebuilds += 1
        return EllState(nbr_idx=jnp.asarray(idx), nbr_w=jnp.asarray(ww),
                        fill=jnp.asarray(fill))


# ------------------------------------------------------------------ epochs --
@partial(jax.jit, static_argnames=("num_vertices", "max_rounds",
                                   "use_kernel", "interpret"))
def ell_relax_until_converged(
    sssp: SSSPState,
    nbr_idx: jax.Array,
    nbr_w: jax.Array,
    frontier: jax.Array,
    *,
    num_vertices: int,
    max_rounds: int = 0,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[SSSPState, RelaxStats]:
    """ELL rendering of relax.relax_until_converged: frontier-masked waves to
    fixpoint.  Same candidate sets, same tie-break => bit-identical results."""

    def cond(carry):
        _, _, frontier, rounds, _ = carry
        go = jnp.any(frontier)
        if max_rounds:
            go = go & (rounds < max_rounds)
        return go

    def body(carry):
        dist, parent, frontier, rounds, msgs = carry
        dist, parent, improved = relax_wave(
            dist, parent, nbr_idx, nbr_w, frontier=frontier,
            use_kernel=use_kernel, interpret=interpret)
        return (dist, parent, improved, rounds + 1,
                msgs + jnp.sum(improved.astype(jnp.int32)))

    dist, parent, _, rounds, msgs = jax.lax.while_loop(
        cond, body,
        (sssp.dist, sssp.parent, frontier, jnp.int32(0), jnp.int32(0)),
    )
    return (
        SSSPState(dist=dist, parent=parent, source=sssp.source),
        RelaxStats(rounds=rounds, messages=msgs),
    )


@partial(jax.jit, static_argnames=("num_vertices", "use_doubling",
                                   "use_kernel", "interpret"))
def ell_invalidate_and_recompute(
    sssp: SSSPState,
    nbr_idx: jax.Array,
    nbr_w: jax.Array,
    seed: jax.Array,
    *,
    num_vertices: int,
    use_doubling: bool = True,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[SSSPState, del_mod.DeleteStats]:
    """Deletion epoch on the ELL block (paper Listings 4/8/9).

    Invalidation reuses the parent-forest marking from core/delete.py (it
    does not touch edges).  The bulk DistanceQuery pull is ONE ELL wave: every
    affected row gathers offers from all in-neighbors at once (+inf sources —
    other affected vertices — and tombstones offer nothing), then ordinary
    frontier-masked waves drain the epoch.

    Safe to call with an all-false seed (non-tree deletions): the state is
    returned unchanged and every stat is 0, which lets the engine skip the
    blocking ``bool(jnp.any(seed))`` host sync entirely (DESIGN.md §2.4).
    """
    any_seed = jnp.any(seed)
    mark = (del_mod.mark_subtree_doubling if use_doubling
            else del_mod.mark_subtree_flood)
    aff, inv_rounds = mark(sssp.parent, seed)
    aff = aff.at[sssp.source].set(False)

    dist = jnp.where(aff, INF, sssp.dist)
    parent = jnp.where(aff, NO_PARENT, sssp.parent)

    # Bulk pull: one unmasked wave, improvements applied to affected rows
    # only (matching the segment path's ``aff[dst]`` edge mask; unaffected
    # rows cannot improve anyway — the pre-deletion state was converged).
    dist_p, parent_p, improved = relax_wave(
        dist, parent, nbr_idx, nbr_w,
        use_kernel=use_kernel, interpret=interpret)
    improved = improved & aff
    dist = jnp.where(improved, dist_p, dist)
    parent = jnp.where(improved, parent_p, parent)

    state1 = SSSPState(dist=dist, parent=parent, source=sssp.source)
    state2, stats = ell_relax_until_converged(
        state1, nbr_idx, nbr_w, improved, num_vertices=num_vertices,
        use_kernel=use_kernel, interpret=interpret)
    zero = jnp.int32(0)
    return state2, del_mod.DeleteStats(
        invalidation_rounds=jnp.where(any_seed, inv_rounds, zero),
        affected=jnp.sum(aff.astype(jnp.int32)),
        recompute_rounds=jnp.where(any_seed, stats.rounds + 1, zero),
        recompute_messages=jnp.where(
            any_seed,
            stats.messages + jnp.sum(improved.astype(jnp.int32)), zero),
    )


# ===========================================================================
# Sliced hybrid backend (DESIGN.md §6): per-slice-K ELL + hub overflow COO
# ===========================================================================
_INT_MAX = jnp.int32(2**31 - 1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SlicedEllState:
    """Device-resident hybrid sliced-ELL + overflow-COO view of the edge set.

    The ELL cells of all slices live in ONE flat buffer (``flat_idx``,
    ``flat_w``): row r's cells occupy ``[base[r], base[r] + rowk[r])`` where
    ``rowk[r]`` is r's slice width.  ``fill`` is the per-row occupancy
    high-water mark, exactly as in ``EllState``.  Hub rows (in-degree above
    the planner's hub threshold) keep their surplus in-edges in the COO
    overflow segment ``(osrc, odst, ow)``; empty/tombstoned entries there
    carry w=+inf (src=dst=0) and never win a min.
    """

    flat_idx: jax.Array  # i32[L] in-neighbor ids (0 where empty/tombstone)
    flat_w: jax.Array    # f32[L] weights (+inf where empty/tombstone)
    fill: jax.Array      # i32[R]
    base: jax.Array      # i32[R] flat offset of each row's first cell
    rowk: jax.Array      # i32[R] each row's slice width
    osrc: jax.Array      # i32[C] overflow in-neighbor ids
    odst: jax.Array      # i32[C] overflow destination rows
    ow: jax.Array        # f32[C] overflow weights (+inf empty/tombstone)


# --------------------------------------------------------------- patch ops --
@jax.jit
def sliced_append(st: SlicedEllState, pos: jax.Array, rows: jax.Array,
                  kpos: jax.Array, src: jax.Array, w: jax.Array
                  ) -> SlicedEllState:
    """Write fresh edges into planner-assigned flat cells (idempotent scatter
    — pad_pow2 repeats are no-ops).  ``pos == base[rows] + kpos``; the
    planner passes both so the device fill marks stay in sync."""
    return dataclasses.replace(
        st,
        flat_idx=st.flat_idx.at[pos].set(src),
        flat_w=st.flat_w.at[pos].set(w),
        fill=st.fill.at[rows].max(kpos + 1),
    )


@jax.jit
def sliced_spill(st: SlicedEllState, opos: jax.Array, src: jax.Array,
                 rows: jax.Array, w: jax.Array) -> SlicedEllState:
    """Append hub-surplus edges into planner-assigned overflow entries
    (idempotent scatter, same pad_pow2 contract as ``sliced_append``)."""
    return dataclasses.replace(
        st,
        osrc=st.osrc.at[opos].set(src),
        odst=st.odst.at[opos].set(rows),
        ow=st.ow.at[opos].set(w),
    )


def _sliced_match(st: SlicedEllState, rows: jax.Array, src: jax.Array,
                  width: int):
    """Locate each (src -> rows) edge's live ELL cell: (flat_pos, found).

    Gathers a ``width``-wide window per row (``width`` = max slice width,
    static) masked to the row's actual slice width — the sliced rendering of
    ``_match_cell``.  Live edges are unique per (row, src), so at most one
    finite-weight cell matches; edges living in the overflow segment simply
    don't match here."""
    m = rows.shape[0]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (m, width), 1)
    pos = jnp.clip(st.base[rows][:, None] + k_iota, 0,
                   st.flat_w.shape[0] - 1)
    in_row = k_iota < st.rowk[rows][:, None]
    hit = (in_row & (st.flat_idx[pos] == src[:, None])
           & jnp.isfinite(st.flat_w[pos]))
    kbest = jnp.argmax(hit, axis=1)
    sel = jnp.take_along_axis(pos, kbest[:, None], axis=1)[:, 0]
    return sel, jnp.any(hit, axis=1)


def _overflow_match(st: SlicedEllState, rows: jax.Array, src: jax.Array):
    """Locate each (src -> rows) edge's live overflow entry: (opos, found)."""
    live = jnp.isfinite(st.ow)[None, :]
    hit = (live & (st.osrc[None, :] == src[:, None])
           & (st.odst[None, :] == rows[:, None]))
    return jnp.argmax(hit, axis=1), jnp.any(hit, axis=1)


@partial(jax.jit, static_argnames=("width",))
def sliced_delete(st: SlicedEllState, rows: jax.Array, src: jax.Array,
                  *, width: int) -> SlicedEllState:
    """Tombstone deleted edges (w := +inf) wherever they live — ELL cell or
    overflow entry — located on device by source-id match.  The max-combine
    (-inf = no-op) makes both scatters order-free under batch padding."""
    sel, found = _sliced_match(st, rows, src, width)
    opos, ofound = _overflow_match(st, rows, src)
    return dataclasses.replace(
        st,
        flat_w=st.flat_w.at[sel].max(jnp.where(found, INF, _NEG_INF)),
        ow=st.ow.at[opos].max(jnp.where(ofound, INF, _NEG_INF)),
    )


@partial(jax.jit, static_argnames=("width",))
def sliced_update_min(st: SlicedEllState, rows: jax.Array, src: jax.Array,
                      w: jax.Array, *, width: int) -> SlicedEllState:
    """Weight-decrease of existing edges (on_duplicate="min"): device-side
    match + min-scatter in both lanes (+inf = no-op when unmatched)."""
    sel, found = _sliced_match(st, rows, src, width)
    opos, ofound = _overflow_match(st, rows, src)
    return dataclasses.replace(
        st,
        flat_w=st.flat_w.at[sel].min(jnp.where(found, w, INF)),
        ow=st.ow.at[opos].min(jnp.where(ofound, w, INF)),
    )


@partial(jax.jit, static_argnames=("width",))
def sliced_invariants(st: SlicedEllState, *, width: int
                      ) -> dict[str, jax.Array]:
    """Occupancy invariants over the flat buffer (mirrors ``ell_invariants``):
    cells between a row's fill mark and its slice width must be empty."""
    R = st.fill.shape[0]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (R, width), 1)
    pos = jnp.clip(st.base[:, None] + k_iota, 0, st.flat_w.shape[0] - 1)
    beyond = (k_iota < st.rowk[:, None]) & (k_iota >= st.fill[:, None])
    return {
        "beyond_fill_empty": jnp.all(
            jnp.where(beyond, jnp.isinf(st.flat_w[pos]), True)),
        "fill_in_range": jnp.all((st.fill >= 0) & (st.fill <= st.rowk)),
    }


# ------------------------------------------------------------------- waves --
@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "use_kernel", "interpret"))
def sliced_relax_wave(dist: jax.Array, parent: jax.Array,
                      st: SlicedEllState, *, widths: tuple[int, ...],
                      slice_rows: int, num_vertices: int,
                      frontier: jax.Array | None = None,
                      use_kernel: bool = False, interpret: bool = True):
    """One hybrid relaxation wave: per-slice ELL gather+row-min (the relax
    kernel, one block per slice) min-combined with a segment-min over the
    overflow COO lane.  Parent ties break toward the smallest in-neighbor id
    ACROSS both lanes — each lane already reports its smallest minimizing id,
    so the combine is a scalar min per row — which keeps (dist, parent)
    bit-identical to the segment and dense-ELL backends."""
    from repro.kernels.relax.ref import ellpack_relax_ref
    from repro.kernels.relax.relax import ellpack_relax

    n = dist.shape[0]
    offers = dist if frontier is None else jnp.where(frontier, dist, INF)

    # runs of equal-width slices are contiguous row-major (R_g, k) blocks in
    # the flat buffer — merge them so the common all-settled-on-one-width
    # case is a single dense wave, not one dispatch per slice.  The Pallas
    # kernel tiles rows in 256-row blocks and requires R_g % min(256, R_g)
    # == 0, so a merged run is split into a multiple-of-256-rows main block
    # plus a sub-256-row remainder block.
    per_blk = max(1, 256 // slice_rows)
    runs: list[list[int]] = []
    for k in widths:
        if runs and runs[-1][0] == k:
            runs[-1][1] += 1
        else:
            runs.append([k, 1])
    groups: list[tuple[int, int]] = []
    for k, cnt in runs:
        main = (cnt // per_blk) * per_blk
        if main:
            groups.append((k, main))
        if cnt - main:
            groups.append((k, cnt - main))
    bests, args_ = [], []
    off = 0
    for k, cnt in groups:                  # static unroll: one block per run
        rows_g = slice_rows * cnt
        blk = slice(off, off + rows_g * k)
        blk_idx = st.flat_idx[blk].reshape(rows_g, k)
        blk_w = st.flat_w[blk].reshape(rows_g, k)
        if use_kernel:
            b, a = ellpack_relax(offers, blk_idx, blk_w, interpret=interpret)
        else:
            b, a = ellpack_relax_ref(offers, blk_idx, blk_w)
        bests.append(b)
        args_.append(a)
        off += rows_g * k
    best = jnp.concatenate(bests)[:n]
    arg = jnp.concatenate(args_)[:n]

    # overflow lane: the segment backend's scatter-min, on the hub surplus
    ocand = offers[st.osrc] + st.ow        # +inf entries can never win
    obest = jnp.minimum(
        jax.ops.segment_min(ocand, st.odst, num_segments=num_vertices), INF)
    ohit = (ocand == obest[st.odst]) & (ocand < INF)
    oarg = jax.ops.segment_min(jnp.where(ohit, st.osrc, _INT_MAX), st.odst,
                               num_segments=num_vertices)

    comb = jnp.minimum(best, obest)
    improved = comb < dist
    ell_key = jnp.where((best == comb) & (best < INF), arg, _INT_MAX)
    coo_key = jnp.where((obest == comb) & (obest < INF), oarg, _INT_MAX)
    new_parent = jnp.minimum(ell_key, coo_key)
    return (jnp.where(improved, comb, dist),
            jnp.where(improved, new_parent, parent),
            improved)


# ------------------------------------------------------------------ epochs --
@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "max_rounds", "use_kernel", "interpret"))
def sliced_relax_until_converged(
    sssp: SSSPState,
    st: SlicedEllState,
    frontier: jax.Array,
    *,
    widths: tuple[int, ...],
    slice_rows: int,
    num_vertices: int,
    max_rounds: int = 0,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[SSSPState, RelaxStats]:
    """Sliced rendering of relax.relax_until_converged: frontier-masked
    hybrid waves to fixpoint.  Same candidate sets, same tie-break =>
    bit-identical results and stats."""

    def cond(carry):
        _, _, frontier, rounds, _ = carry
        go = jnp.any(frontier)
        if max_rounds:
            go = go & (rounds < max_rounds)
        return go

    def body(carry):
        dist, parent, frontier, rounds, msgs = carry
        dist, parent, improved = sliced_relax_wave(
            dist, parent, st, widths=widths, slice_rows=slice_rows,
            num_vertices=num_vertices, frontier=frontier,
            use_kernel=use_kernel, interpret=interpret)
        return (dist, parent, improved, rounds + 1,
                msgs + jnp.sum(improved.astype(jnp.int32)))

    dist, parent, _, rounds, msgs = jax.lax.while_loop(
        cond, body,
        (sssp.dist, sssp.parent, frontier, jnp.int32(0), jnp.int32(0)),
    )
    return (
        SSSPState(dist=dist, parent=parent, source=sssp.source),
        RelaxStats(rounds=rounds, messages=msgs),
    )


@partial(jax.jit, static_argnames=("widths", "slice_rows", "num_vertices",
                                   "use_doubling", "use_kernel", "interpret"))
def sliced_invalidate_and_recompute(
    sssp: SSSPState,
    st: SlicedEllState,
    seed: jax.Array,
    *,
    widths: tuple[int, ...],
    slice_rows: int,
    num_vertices: int,
    use_doubling: bool = True,
    use_kernel: bool = False,
    interpret: bool = True,
) -> tuple[SSSPState, del_mod.DeleteStats]:
    """Deletion epoch on the hybrid layout — structurally identical to
    ``ell_invalidate_and_recompute`` (same marking, same bulk-pull-as-one-
    unmasked-wave, same stat gating on ``any(seed)``), with the hybrid wave
    so hub rows also pull offers through the overflow lane."""
    any_seed = jnp.any(seed)
    mark = (del_mod.mark_subtree_doubling if use_doubling
            else del_mod.mark_subtree_flood)
    aff, inv_rounds = mark(sssp.parent, seed)
    aff = aff.at[sssp.source].set(False)

    dist = jnp.where(aff, INF, sssp.dist)
    parent = jnp.where(aff, NO_PARENT, sssp.parent)

    dist_p, parent_p, improved = sliced_relax_wave(
        dist, parent, st, widths=widths, slice_rows=slice_rows,
        num_vertices=num_vertices, use_kernel=use_kernel,
        interpret=interpret)
    improved = improved & aff
    dist = jnp.where(improved, dist_p, dist)
    parent = jnp.where(improved, parent_p, parent)

    state1 = SSSPState(dist=dist, parent=parent, source=sssp.source)
    state2, stats = sliced_relax_until_converged(
        state1, st, improved, widths=widths, slice_rows=slice_rows,
        num_vertices=num_vertices, use_kernel=use_kernel,
        interpret=interpret)
    zero = jnp.int32(0)
    return state2, del_mod.DeleteStats(
        invalidation_rounds=jnp.where(any_seed, inv_rounds, zero),
        affected=jnp.sum(aff.astype(jnp.int32)),
        recompute_rounds=jnp.where(any_seed, stats.rounds + 1, zero),
        recompute_messages=jnp.where(
            any_seed,
            stats.messages + jnp.sum(improved.astype(jnp.int32)), zero),
    )


# ------------------------------------------------------------ host planner --
class SlicedPlan(NamedTuple):
    """One ADD batch's placement: ELL cells + overflow spills (all numpy)."""

    pos: np.ndarray    # i32[e] flat ELL cell positions (base[row] + kpos)
    rows: np.ndarray   # i32[e]
    kpos: np.ndarray   # i32[e]
    src: np.ndarray    # i32[e]
    w: np.ndarray      # f32[e]
    opos: np.ndarray   # i32[s] overflow entry positions
    osrc: np.ndarray   # i32[s]
    orows: np.ndarray  # i32[s]
    ow: np.ndarray     # f32[s]


class SlicedEllPlanner:
    """Host control plane for the hybrid layout (DESIGN.md §6): assigns ELL
    cells and overflow entries, detects per-slice / overflow exhaustion, and
    rebuilds from the host COO mirror with monotone per-slice capacity
    doubling (each slice's width doubles independently, capped at ``hub_k``;
    the overflow capacity doubles when the live surplus outgrows it).

    Hub threshold policy: a row whose fill reaches ``hub_k`` is a hub — its
    further in-edges spill to the overflow segment instead of widening the
    whole slice.  Rows below the threshold that outgrow their slice width
    trigger a rebuild, which doubles that slice's width only.
    """

    def __init__(self, num_vertices: int, *, slice_rows: int = 256,
                 hub_k: int = 32, init_k: int = 2):
        self.n = num_vertices
        self.sr = min(_next_pow2(max(slice_rows, 1)),
                      _next_pow2(max(num_vertices, 1)))
        self.rows = -(-num_vertices // self.sr) * self.sr
        self.n_slices = self.rows // self.sr
        self.hub_k = _next_pow2(max(hub_k, 1))
        init_k = min(_next_pow2(max(init_k, 1)), self.hub_k)
        self.widths = [init_k] * self.n_slices
        self.fill = np.zeros(self.rows, np.int32)
        self.ocap = 8
        self.ofill = 0
        self.rebuilds = 0
        self.spills = 0
        self._recompute_geometry()

    def _recompute_geometry(self) -> None:
        _, self.rowk, self.base, self.cells = csr_mod.sliced_geometry(
            self.widths, self.sr)

    @property
    def max_width(self) -> int:
        return max(self.widths)

    def empty_state(self) -> SlicedEllState:
        return SlicedEllState(
            flat_idx=jnp.zeros(self.cells, jnp.int32),
            flat_w=jnp.full(self.cells, INF, jnp.float32),
            fill=jnp.zeros(self.rows, jnp.int32),
            base=jnp.asarray(self.base, jnp.int32),
            rowk=jnp.asarray(self.rowk, jnp.int32),
            osrc=jnp.zeros(self.ocap, jnp.int32),
            odst=jnp.zeros(self.ocap, jnp.int32),
            ow=jnp.full(self.ocap, INF, jnp.float32),
        )

    def plan_appends(self, rows: np.ndarray, src: np.ndarray,
                     w: np.ndarray) -> SlicedPlan | None:
        """Assign each fresh edge an ELL cell past its row's fill mark, or an
        overflow entry once the row is at the hub threshold.  Returns None
        when a sub-threshold row outgrows its slice width or the overflow
        segment is full — the caller must rebuild instead."""
        m = len(rows)
        z32 = np.empty(0, np.int32)
        zf = np.empty(0, np.float32)
        if m == 0:
            return SlicedPlan(z32, z32, z32, z32, zf, z32, z32, z32, zf)
        rows = np.asarray(rows, np.int64)
        kcand = self.fill[rows] + _rank_within_rows(rows)
        to_ell = kcand < self.rowk[rows]
        over = ~to_ell
        # overflow is only legal past the hub threshold; a sub-threshold row
        # outgrowing its slice width means the slice must double -> rebuild
        if bool((over & (self.rowk[rows] < self.hub_k)).any()):
            return None
        n_spill = int(over.sum())
        if self.ofill + n_spill > self.ocap:
            return None
        # commit
        erows = rows[to_ell]
        ekpos = kcand[to_ell].astype(np.int32)
        np.maximum.at(self.fill, erows, ekpos + 1)
        sp_rank = np.cumsum(over) - 1
        opos = (self.ofill + sp_rank[over]).astype(np.int32)
        self.ofill += n_spill
        self.spills += n_spill
        return SlicedPlan(
            pos=(self.base[erows] + ekpos).astype(np.int32),
            rows=erows.astype(np.int32), kpos=ekpos,
            src=np.asarray(src)[to_ell], w=np.asarray(w)[to_ell],
            opos=opos, osrc=np.asarray(src)[over],
            orows=rows[over].astype(np.int32), ow=np.asarray(w)[over])

    def rebuild(self, src: np.ndarray, dst: np.ndarray, w: np.ndarray
                ) -> SlicedEllState:
        """Rebuild the device layout from the live COO edge set (host
        mirror): tombstones compact away, each slice's width grows to the
        next pow2 of 2x its capped max in-degree (monotone, <= hub_k), and
        the overflow capacity doubles past the live surplus."""
        deg = np.zeros(self.rows, np.int64)
        if len(dst):
            deg[:self.n] = np.bincount(dst, minlength=self.n)
        capped = np.minimum(deg, self.hub_k)
        slice_max = capped.reshape(self.n_slices, self.sr).max(axis=1)
        self.widths = [
            max(cur, min(self.hub_k, _next_pow2(max(2 * int(mx), 1))))
            for cur, mx in zip(self.widths, slice_max)]
        surplus = int((deg - capped).sum())
        self.ocap = max(self.ocap, _next_pow2(max(2 * surplus, 8)))
        flat_idx, flat_w, fill, _, osrc, odst, ow, n_over = \
            csr_mod.sliced_ell_from_coo(
                self.n, src, dst, w, slice_rows=self.sr, hub_k=self.hub_k,
                n_rows=self.rows, widths=self.widths,
                overflow_capacity=self.ocap)
        self.fill = fill
        self.ofill = n_over
        self.rebuilds += 1
        self._recompute_geometry()
        return SlicedEllState(
            flat_idx=jnp.asarray(flat_idx), flat_w=jnp.asarray(flat_w),
            fill=jnp.asarray(fill), base=jnp.asarray(self.base, jnp.int32),
            rowk=jnp.asarray(self.rowk, jnp.int32),
            osrc=jnp.asarray(osrc), odst=jnp.asarray(odst),
            ow=jnp.asarray(ow))
