"""SSSPDelEngine — the paper's runtime loop (paper §4.1) as a host
orchestrator over jitted device epochs.

Faithful behaviour (defaults):
  * runs of consecutive ADD events are ingested as one batch and drained by
    monotone relaxation (the paper's runtime likewise drains its topology
    buffer before algorithmic messages, and insertion mode is order-free);
  * every DEL event triggers the stop-the-world sequence: converge, apply the
    single deletion, invalidation + recomputation, converge;
  * QUERY markers enforce an epoch and snapshot (dist, parent).

Beyond-paper switches:
  * ``batch_deletions=True`` — coalesce a run of consecutive DELs into one
    invalidation+recompute epoch (union of affected subtrees; see DESIGN.md).
  * ``use_doubling`` — pointer-doubling invalidation (default True; set False
    for the paper's wave-by-wave flood).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import delete as del_mod
from repro.core import events as ev
from repro.core import ingest, relax
from repro.core.state import EdgePool, GraphState, SSSPState


@dataclasses.dataclass
class EngineConfig:
    num_vertices: int
    edge_capacity: int
    source: int
    use_doubling: bool = True
    batch_deletions: bool = False
    on_duplicate: str = "ignore"
    validate_every: int = 0     # if >0, run oracle check every k queries (tests)


@dataclasses.dataclass
class QueryResult:
    dist: np.ndarray
    parent: np.ndarray
    latency_s: float
    epoch_stats: dict[str, Any]


class SSSPDelEngine:
    """Host orchestrator; all heavy lifting is jitted device code."""

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self.alloc = ingest.SlotAllocator(cfg.edge_capacity, cfg.on_duplicate)
        self.state = GraphState.init(cfg.num_vertices, cfg.edge_capacity, cfg.source)
        # counters (host-side, for benchmarks)
        self.n_epochs = 0
        self.n_rounds = 0
        self.n_messages = 0
        self.n_adds = 0
        self.n_dels = 0
        self._last_parent: np.ndarray | None = None

    # ------------------------------------------------------------------ adds
    def _ingest_adds(self, batch: ev.EventBatch) -> None:
        slots, src, dst, w = self.alloc.plan_adds(batch.src, batch.dst, batch.w)
        if len(slots) == 0:
            return
        slots_p, src_p, dst_p, w_p = ingest.pad_pow2(slots, src, dst, w)
        edges = ingest.apply_adds(self.state.edges, jnp.asarray(slots_p),
                                  jnp.asarray(src_p), jnp.asarray(dst_p),
                                  jnp.asarray(w_p))
        # Frontier = tails of the inserted edges (paper Listing 3: tail offers
        # its distance to the head).  Relaxing from the tails delivers exactly
        # those offers (plus no-op re-offers along other out-edges).
        frontier = relax.frontier_from_vertices(
            jnp.asarray(src), self.cfg.num_vertices)
        sssp, stats = relax.relax_until_converged(
            self.state.sssp, edges, frontier, num_vertices=self.cfg.num_vertices)
        self.state = dataclasses.replace(self.state, edges=edges, sssp=sssp)
        self.n_adds += len(slots)
        self.n_epochs += 1
        self.n_rounds += int(stats.rounds)
        self.n_messages += int(stats.messages)

    # ------------------------------------------------------------------ dels
    def _ingest_dels(self, batch: ev.EventBatch) -> None:
        if self.cfg.batch_deletions:
            groups = [(batch.src, batch.dst)]
        else:
            groups = [(batch.src[i:i + 1], batch.dst[i:i + 1])
                      for i in range(len(batch.src))]
        for gsrc, gdst in groups:
            slots, psrc, pdst = self.alloc.plan_dels(gsrc, gdst)
            if len(slots) == 0:
                continue
            slots_p, psrc_p, pdst_p = ingest.pad_pow2(slots, psrc, pdst)
            # Epoch before the deletion is implicit: every prior batch ran to
            # convergence.  Seed from the *pre-deletion* tree, then deactivate.
            seed = del_mod.deletion_seed_for_edges(
                self.state.sssp, jnp.asarray(psrc_p), jnp.asarray(pdst_p),
                self.cfg.num_vertices)
            edges = ingest.apply_dels(self.state.edges, jnp.asarray(slots_p))
            if bool(jnp.any(seed)):
                sssp, dstats = del_mod.invalidate_and_recompute(
                    self.state.sssp, edges, seed,
                    num_vertices=self.cfg.num_vertices,
                    use_doubling=self.cfg.use_doubling)
                self.n_rounds += int(dstats.invalidation_rounds) + int(dstats.recompute_rounds)
                self.n_messages += int(dstats.recompute_messages) + int(dstats.affected)
            else:
                sssp = self.state.sssp  # non-tree deletion: no algorithmic work
            self.state = dataclasses.replace(self.state, edges=edges, sssp=sssp)
            self.n_dels += len(slots)
            self.n_epochs += 1

    # ---------------------------------------------------------------- stream
    def ingest_log(self, log: ev.EventLog,
                   on_query: Callable[[QueryResult], None] | None = None) -> list[QueryResult]:
        """Drive the engine over an event log; returns query results."""
        results: list[QueryResult] = []
        for batch in log.runs():
            if batch.kind == ev.ADD:
                self._ingest_adds(batch)
            elif batch.kind == ev.DEL:
                self._ingest_dels(batch)
            else:
                res = self.query()
                results.append(res)
                if on_query is not None:
                    on_query(res)
        return results

    # ----------------------------------------------------------------- query
    def query(self) -> QueryResult:
        """State collection (paper §3): epoch is already enforced (every batch
        runs to convergence), so the query cost is the device->host readback
        plus any residual convergence work (none in faithful mode)."""
        t0 = time.perf_counter()
        dist = np.asarray(jax.device_get(self.state.sssp.dist))
        parent = np.asarray(jax.device_get(self.state.sssp.parent))
        dt = time.perf_counter() - t0
        stats = {
            "epochs": self.n_epochs, "rounds": self.n_rounds,
            "messages": self.n_messages, "adds": self.n_adds, "dels": self.n_dels,
        }
        return QueryResult(dist=dist, parent=parent, latency_s=dt, epoch_stats=stats)

    def stability_vs_prev(self, parent: np.ndarray) -> float:
        """Paper §5.4: fraction of vertices whose predecessor is unchanged
        (over vertices present in both results)."""
        if self._last_parent is None:
            self._last_parent = parent.copy()
            return 1.0
        prev = self._last_parent
        both = (prev >= 0) & (parent >= 0)
        frac = float(np.mean(prev[both] == parent[both])) if both.any() else 1.0
        self._last_parent = parent.copy()
        return frac

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self) -> dict[str, np.ndarray]:
        """O(N+E) snapshot for fault tolerance (see train/checkpoint.py for
        the sharded writer used at scale)."""
        e, s = self.state.edges, self.state.sssp
        return {
            "src": np.asarray(e.src), "dst": np.asarray(e.dst),
            "w": np.asarray(e.w), "active": np.asarray(e.active),
            "dist": np.asarray(s.dist), "parent": np.asarray(s.parent),
            "source": np.asarray(s.source), "cursor": np.asarray(self.state.cursor),
        }

    def restore(self, ckpt: dict[str, np.ndarray]) -> None:
        self.state = GraphState(
            edges=EdgePool(jnp.asarray(ckpt["src"]), jnp.asarray(ckpt["dst"]),
                           jnp.asarray(ckpt["w"]), jnp.asarray(ckpt["active"])),
            sssp=SSSPState(jnp.asarray(ckpt["dist"]), jnp.asarray(ckpt["parent"]),
                           jnp.asarray(ckpt["source"])),
            cursor=jnp.asarray(ckpt["cursor"]),
        )
        # rebuild host allocator from the pool
        self.alloc = ingest.SlotAllocator(self.cfg.edge_capacity, self.cfg.on_duplicate)
        act = np.asarray(ckpt["active"])
        src = np.asarray(ckpt["src"]); dst = np.asarray(ckpt["dst"])
        self.alloc.free = [i for i in range(self.cfg.edge_capacity - 1, -1, -1) if not act[i]]
        self.alloc.slot_of = {(int(src[i]), int(dst[i])): i
                              for i in np.nonzero(act)[0].tolist()}
