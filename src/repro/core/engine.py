"""SSSPDelEngine — the paper's runtime loop (paper §4.1) as a host
orchestrator over jitted device epochs.

Faithful behaviour (defaults):
  * runs of consecutive ADD events are ingested as one batch and drained by
    monotone relaxation (the paper's runtime likewise drains its topology
    buffer before algorithmic messages, and insertion mode is order-free);
  * every DEL event triggers the stop-the-world sequence: converge, apply the
    single deletion, invalidation + recomputation, converge;
  * QUERY markers enforce an epoch and snapshot (dist, parent).

Beyond-paper switches:
  * ``sources=(s0, s1, ...)`` — batched multi-source serving (DESIGN.md §8):
    the engine maintains stacked ``[S, N]`` dist/parent state, one tree per
    source, over ONE shared graph layout; every epoch runs vmapped over the
    source axis and is bit-identical per lane to S independent engines
    (``source`` is ignored when ``sources`` is set).
  * ``batch_deletions=True`` — coalesce a run of consecutive DELs into one
    invalidation+recompute epoch (union of affected subtrees; DESIGN.md §3).
  * ``use_doubling`` — pointer-doubling invalidation (default True; set False
    for the paper's wave-by-wave flood).
  * ``relax_backend`` — any registered ``RelaxBackend`` (core/backends/,
    DESIGN.md §7): "segment" (scatter-min over the COO pool), "ellpack"
    (dense gather + row-min over an incrementally maintained ELLPACK block;
    the Pallas kernel's layout — DESIGN.md §2), or "sliced" (hub-aware
    hybrid: per-slice-width ELL + overflow COO lane for power-law hubs —
    DESIGN.md §6).  The engine itself is backend-agnostic: the ingest path
    calls the protocol's ``apply_adds`` / ``apply_dels`` / ``relax`` /
    ``delete`` hooks and never branches on the backend name.

Host-sync rules (DESIGN.md §2.4): the ingest loop never blocks on device
values.  Round/message stats accumulate in device scalars and are only read
back inside ``query()``; deletion epochs run unconditionally (an all-false
seed is a cheap device no-op) instead of the old ``bool(jnp.any(seed))``
round-trip per deletion.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends as bk_mod
from repro.core import buckets
from repro.core import delete as del_mod
from repro.core import events as ev
from repro.core import frontier as frontier_mod
from repro.core import ingest, relax
from repro.core.backends import RELAX_BACKENDS
from repro.core.state import EdgePool, GraphState, SSSPState
from repro.core.stream import QueryResult, StreamEngineBase
from repro.obs import WatchdogConfig

__all__ = ["EngineConfig", "QueryResult", "SSSPDelEngine", "RELAX_BACKENDS"]


@dataclasses.dataclass
class EngineConfig:
    num_vertices: int
    edge_capacity: int
    source: int
    use_doubling: bool = True
    batch_deletions: bool = False
    on_duplicate: str = "ignore"
    validate_every: int = 0     # if >0, run oracle check every k queries (tests)
    relax_backend: str = "segment"
    ell_block_rows: int = 256   # relax-kernel row tile (rebuilds pad to this)
    ell_init_k: int = 8         # initial ELL width; doubles on overflow
    ell_use_kernel: bool | None = None  # None = Pallas kernel iff on TPU
    # "sliced" backend knobs (DESIGN.md §6)
    sliced_slice_rows: int = 256  # rows per degree slice (per-slice K)
    sliced_hub_k: int = 32        # hub threshold: rows past it spill to COO
    sliced_init_k: int = 2        # initial per-slice width; doubles at rebuild
    sliced_fused: bool = False    # fused Pallas wave kernel (DESIGN.md §9.4)
    # bucketed delta-stepping schedule (DESIGN.md §9): "rounds" settles every
    # epoch to fixpoint; "buckets" defers convergence work into a pending
    # set and drains it bucket-by-bucket at query/checkpoint time
    wave_schedule: str = "rounds"
    # delta; inf = one bucket (plain converge); "auto" picks a pow2-quantized
    # percentile of the live pool weights at drain time (DESIGN.md §9.5)
    bucket_width: float | str = 1.0
    # frontier-compacted sparse epochs (DESIGN.md §12): "sparse" routes every
    # push epoch through the compacted worklist path (the capacity ladder's
    # dense fallback bounds the regression when occupancy blows up); "auto"
    # routes per epoch from host-known occupancy bounds
    frontier_mode: str = "dense"
    frontier_cap: int = 0           # top ladder rung; 0 = derive (~N/64)
    frontier_kernel: bool = False   # Pallas gathered-rows wave kernel
    # batched multi-source serving (DESIGN.md §8); None = single-source
    sources: tuple[int, ...] | None = None
    # observability (DESIGN.md §10): device-side counter registry + span
    # tracer + flight recorder; off by default — the obs_overhead bench +
    # check_regression gate hold instrumented ingest >= 0.95x uninstrumented
    observability: bool = False
    obs_flight_capacity: int = 128
    # stall/divergence watchdog (§10.8): a WatchdogConfig arms it (only
    # meaningful with observability=True); None = off
    obs_watchdog: "WatchdogConfig | None" = None
    # control-plane implementation (DESIGN.md §11): "columnar" (numpy
    # open-addressing index; the paper-scale default) or "dict" (the Python
    # reference).  Bit-identical outputs either way.
    alloc_impl: str = "columnar"

    def __post_init__(self):
        # fail at construction with the valid set, not deep in layout init
        bk_mod.validate_backend_config(self)
        ingest.allocator_cls(self.alloc_impl)  # raises on unknown impl
        if self.obs_flight_capacity < 1:
            raise ValueError(f"obs_flight_capacity must be >= 1; got "
                             f"{self.obs_flight_capacity}")
        if self.sources is not None:
            self.sources = tuple(int(s) for s in self.sources)
            bad = [s for s in self.sources
                   if not 0 <= s < self.num_vertices]
            if not self.sources or bad:
                raise ValueError(
                    f"sources must be non-empty vertex ids in "
                    f"[0, {self.num_vertices}); got {self.sources}")


class SSSPDelEngine(StreamEngineBase):
    """Host orchestrator; all heavy lifting is jitted device code.

    Stream dispatch, lazy device-scalar stats, and the stability metric are
    shared with the sharded engine via ``StreamEngineBase`` (core/stream.py);
    everything layout-specific lives behind ``self.backend``
    (core/backends/, DESIGN.md §7).
    """

    def __init__(self, cfg: EngineConfig):
        super().__init__(sources=cfg.sources,
                         observability=cfg.observability,
                         flight_capacity=cfg.obs_flight_capacity,
                         watchdog=cfg.obs_watchdog)
        self.cfg = cfg
        self.alloc = ingest.make_allocator(cfg.edge_capacity,
                                           cfg.on_duplicate, cfg.alloc_impl)
        self.state = GraphState.init(cfg.num_vertices, cfg.edge_capacity, cfg.source)
        if self.sources is not None:
            # stacked [S, N] trees over the single shared edge pool
            self.state = dataclasses.replace(
                self.state, sssp=SSSPState.init_batched(
                    cfg.num_vertices, self.sources))
        on_tpu = jax.default_backend() == "tpu"
        use_kernel = on_tpu if cfg.ell_use_kernel is None else cfg.ell_use_kernel
        self._use_kernel, self._interpret = use_kernel, not on_tpu
        # "auto" starts on the dense ELL layout and falls back to sliced when
        # a rebuild reports hub blowup (backends/base.py ELL_BLOWUP_RATIO)
        self._auto = cfg.relax_backend == bk_mod.AUTO_BACKEND
        self.backend_name = "ellpack" if self._auto else cfg.relax_backend
        self.backend = bk_mod.make_backend(
            self.backend_name, cfg, use_kernel=use_kernel,
            interpret=not on_tpu)
        self.bucketed = cfg.wave_schedule == "buckets"
        self._pend = buckets.empty_pending(
            cfg.num_vertices,
            None if self.sources is None else len(self.sources))
        # frontier-compacted sparse path (DESIGN.md §12): OUT-adjacency
        # sidecar + capacity ladder; maintained whenever the mode can route
        # sparse so the routing decision stays a pure host policy choice
        self._sparse = cfg.frontier_mode != "dense"
        if self._sparse:
            self._out = frontier_mod.OutAdjacency(cfg.num_vertices)
            self._caps = frontier_mod.capacity_ladder(cfg.num_vertices,
                                                      cfg.frontier_cap)
        # host-side upper bound on pending-push occupancy (the "auto" drain
        # signal; reset per drain, pinned to N when a deletion's affected
        # set is unknown host-side)
        self._pend_bound = 0
        # bucket_width="auto" resolution cache: (resolved width, live-edge
        # estimate at resolution) — re-resolved when the pool doubles/halves
        self._bw_cache: tuple[float, int] | None = None

    # -------------------------------------------------- sparse/width policy
    def _route_sparse(self, occupancy_bound: int) -> bool:
        """Host-only routing: "sparse" always takes the compacted path (the
        device-side ladder bounds blowup); "auto" takes it only when the
        host-known occupancy upper bound fits the top rung — no device
        readback either way (DESIGN.md §2.4/§12.3)."""
        if not self._sparse:
            return False
        if self.cfg.frontier_mode == "sparse":
            return True
        return occupancy_bound <= self._caps[-1]

    def _fold_occupancy(self, occ) -> None:
        if self.obs.enabled:
            self.obs.counters.add(
                "frontier_occupancy",
                occ if getattr(occ, "ndim", 0) == 0 else jnp.sum(occ))

    def _bucket_width(self) -> float:
        """Resolve ``bucket_width="auto"`` host-side: the pow2-quantized
        median of the live pool weights (delta ~ typical edge weight groups
        each improvement chain into a handful of buckets — the §9 follow-up).
        Quantization plus a doubling/halving re-resolve policy bounds the
        distinct static widths the jitted drains see."""
        if self.cfg.bucket_width != "auto":
            return self.cfg.bucket_width
        live_est = max(1, self.n_adds - self.n_dels)
        if self._bw_cache is not None:
            width, at = self._bw_cache
            if at / 2 <= live_est <= at * 2:
                return width
        w = self.alloc.active_coo()[2]
        if len(w) == 0:
            width = 1.0
        else:
            med = max(float(np.percentile(w, 50.0)), 1e-6)
            width = float(2.0 ** np.round(np.log2(med)))
        self._bw_cache = (width, live_est)
        return width

    # ------------------------------------------------------------------ adds
    def _ingest_adds(self, batch: ev.EventBatch) -> None:
        plan = self.alloc.plan_adds(batch.src, batch.dst, batch.w)
        if len(plan.slots) == 0:
            return
        with self.obs.epoch("add_epoch", events=len(plan.slots)):
            slots_p, src_p, dst_p, w_p = ingest.pad_pow2(
                plan.slots, plan.src, plan.dst, plan.w)
            edges = ingest.apply_adds(self.state.edges, jnp.asarray(slots_p),
                                      jnp.asarray(src_p), jnp.asarray(dst_p),
                                      jnp.asarray(w_p))
            # Frontier = tails of the inserted edges (paper Listing 3: tail
            # offers its distance to the head).  Relaxing from the tails
            # delivers exactly those offers (plus no-op re-offers along
            # other out-edges).
            frontier = relax.frontier_from_vertices(
                jnp.asarray(plan.src), self.cfg.num_vertices)
            self.backend.apply_adds(plan, self.alloc)
            if self._sparse:
                # OUT-adjacency sidecar rides along with every layout patch
                # so the per-epoch routing stays a free policy choice
                self._out.apply_adds(plan, self.alloc)
            if self._auto and getattr(self.backend, "blowup", False):
                self._fallback_to_sliced()
            self.obs.note_layout(self.backend.layout_counters())
            if self.obs.enabled:
                # frontier = distinct inserted tails — the host plan already
                # knows the figure the device mask encodes, so counting here
                # costs no device dispatch in the hot ingest path (§10.4);
                # the device-counter path carries the drain-side figures
                # (drain_waves, pending occupancy) the epochs computed anyway
                nf = len(np.unique(plan.src))
                self.obs.counters.inc("frontier", nf)
                # one occupancy-histogram sample per ADD epoch (§10.6):
                # sum(hist_frontier_occupancy) == add_epochs
                self.obs.hist_host("hist_frontier_occupancy", nf)
                if self.obs.watchdog is not None:
                    self.obs.watchdog.observe(
                        "add_epoch", 0.0, {"frontier": nf})
            if self.bucketed:
                # deferred settle (DESIGN.md §9): record the push obligation
                # and return — the drain delivers the offers bucket-by-bucket
                self._pend = buckets.enqueue_push(self._pend, frontier,
                                                  self.state.sssp.dist)
                self._pend_bound += len(np.unique(plan.src))
                self.state = dataclasses.replace(self.state, edges=edges)
            elif self._route_sparse(len(np.unique(plan.src))):
                sp_fn = (frontier_mod.sparse_relax_until_converged
                         if self.sources is None
                         else frontier_mod.sparse_relax_batched)
                sssp, stats, occ = sp_fn(
                    self.state.sssp, edges, self._out.state, frontier,
                    num_vertices=self.cfg.num_vertices, caps=self._caps,
                    use_kernel=self.cfg.frontier_kernel,
                    interpret=self._interpret)
                self.state = dataclasses.replace(self.state, edges=edges,
                                                 sssp=sssp)
                self._accumulate_relax(stats)
                self._fold_occupancy(occ)
            else:
                relax_fn = (self.backend.relax if self.sources is None
                            else self.backend.relax_batched)
                sssp, stats = relax_fn(self.state.sssp, edges, frontier)
                self.state = dataclasses.replace(self.state, edges=edges,
                                                 sssp=sssp)
                self._accumulate_relax(stats)
            self.n_adds += len(plan.slots)
            self.n_epochs += 1

    def _fallback_to_sliced(self) -> None:
        """relax_backend="auto": the dense-ELL rebuild just reported hub
        blowup (K*N cells >> live edges) — swap to the sliced/hybrid layout,
        rebuilt from the pool mirror exactly as a restore would."""
        self._auto = False
        self.backend_name = "sliced"
        self.backend = bk_mod.make_backend(
            "sliced", self.cfg, use_kernel=self._use_kernel,
            interpret=self._interpret)
        self.backend.restore(self.alloc)

    # ------------------------------------------------------------------ dels
    def _ingest_dels(self, batch: ev.EventBatch) -> None:
        for gsrc, gdst in self._deletion_groups(batch):
            slots, psrc, pdst = self.alloc.plan_dels(gsrc, gdst)
            if len(slots) == 0:
                continue
            with self.obs.epoch("del_epoch", events=len(slots)):
                self._del_group(slots, psrc, pdst)

    def _del_group(self, slots: np.ndarray, psrc: np.ndarray,
                   pdst: np.ndarray) -> None:
        """One dispatched deletion epoch (one span, one flight record)."""
        slots_p, psrc_p, pdst_p = ingest.pad_pow2(slots, psrc, pdst)
        if self._sparse:
            self._out.apply_dels(psrc_p, pdst_p)
        if self.bucketed:
            # ONE fused dispatch: deactivate + seed + mark + invalidate,
            # recomputation deferred to the drain (DESIGN.md §9).  The
            # layout tombstones still stage as their own patch op.
            self.backend.apply_dels(pdst_p, psrc_p)
            # the affected subtree's size is device-only knowledge; pin the
            # pending bound to N so the "auto" drain routes dense
            self._pend_bound = self.cfg.num_vertices
            fn = (buckets.lazy_delete if self.sources is None
                  else buckets.lazy_delete_batched)
            sssp, edges, self._pend, dstats = fn(
                self.state.sssp, self.state.edges, self._pend,
                jnp.asarray(psrc_p), jnp.asarray(pdst_p),
                jnp.asarray(slots_p),
                num_vertices=self.cfg.num_vertices,
                use_doubling=self.cfg.use_doubling)
            self.state = dataclasses.replace(self.state, edges=edges,
                                             sssp=sssp)
            self._accumulate_delete(dstats)
            self.n_dels += len(slots)
            self.n_epochs += 1
            return
        # Epoch before the deletion is implicit: every prior batch ran to
        # convergence.  Seed from the *pre-deletion* tree, then
        # deactivate.  Batched lanes seed independently — whether a
        # deleted edge was a tree edge depends on each lane's forest.
        if self.sources is None:
            seed = del_mod.deletion_seed_for_edges(
                self.state.sssp, jnp.asarray(psrc_p),
                jnp.asarray(pdst_p), self.cfg.num_vertices)
            delete_fn = self.backend.delete
        else:
            seed = del_mod.deletion_seed_for_edges_batched(
                self.state.sssp, jnp.asarray(psrc_p),
                jnp.asarray(pdst_p), self.cfg.num_vertices)
            delete_fn = self.backend.delete_batched
        edges = ingest.apply_dels(self.state.edges, jnp.asarray(slots_p))
        self.backend.apply_dels(pdst_p, psrc_p)
        # Non-tree deletions (all-false seed) are a device no-op with
        # zeroed stats — cheaper than syncing on bool(jnp.any(seed)).
        # Sparse routing for DELs is mode="sparse" only: the affected
        # region's size is device-only knowledge, so "auto" stays dense.
        if self._sparse and self.cfg.frontier_mode == "sparse":
            sp_fn = (frontier_mod.sparse_invalidate_and_recompute
                     if self.sources is None
                     else frontier_mod.sparse_delete_batched)
            sssp, dstats, occ = sp_fn(
                self.state.sssp, edges, self._out.state, seed,
                num_vertices=self.cfg.num_vertices, caps=self._caps,
                use_doubling=self.cfg.use_doubling,
                use_kernel=self.cfg.frontier_kernel,
                interpret=self._interpret)
            self._fold_occupancy(occ)
        else:
            sssp, dstats = delete_fn(self.state.sssp, edges, seed)
        self.state = dataclasses.replace(self.state, edges=edges, sssp=sssp)
        self._accumulate_delete(dstats)
        self.n_dels += len(slots)
        self.n_epochs += 1

    # ----------------------------------------------------------------- query
    def drain(self) -> None:
        """Settle the bucketed schedule's pending work (no-op under the
        rounds schedule or with nothing pending — the drain's cond-gated
        pull and empty while loop cost one cheap dispatch, no host sync).
        Public so benches/tests can force a converged tree without the
        query()'s readback."""
        if not self.bucketed:
            return
        if self.obs.enabled:
            # bucket occupancy at drain entry (lazy device sums, §10.1);
            # [S] per-lane vectors on a batched engine
            occ_push, occ_pull = buckets.pending_occupancy(self._pend)
            occ_dim = None if self.sources is None else "lane"
            self.obs.counters.add("pending_push", occ_push, dim=occ_dim)
            self.obs.counters.add("pending_pull", occ_pull, dim=occ_dim)
        with self.obs.epoch("drain"):
            bw = self._bucket_width()
            if self._route_sparse(self._pend_bound):
                sp_fn = (frontier_mod.sparse_drain if self.sources is None
                         else frontier_mod.sparse_drain_batched)
                sssp, self._pend, stats, occ = sp_fn(
                    self.state.sssp, self.state.edges, self._out.state,
                    self._pend, num_vertices=self.cfg.num_vertices,
                    caps=self._caps, bucket_width=bw,
                    use_kernel=self.cfg.frontier_kernel,
                    interpret=self._interpret)
                self._fold_occupancy(occ)
            else:
                drain_fn = (self.backend.drain if self.sources is None
                            else self.backend.drain_batched)
                sssp, self._pend, stats = drain_fn(
                    self.state.sssp, self.state.edges, self._pend,
                    bucket_width=bw)
            self._pend_bound = 0
            self.state = dataclasses.replace(self.state, sssp=sssp)
            self._accumulate_relax(stats)
            if self.obs.enabled:
                # waves this drain spent (the §9 bucket pacing figure)
                self.obs.counters.add("drain_waves", stats.rounds)

    def _snapshot(self, lane: int | None) -> tuple[np.ndarray, np.ndarray]:
        """Device->host readback (latency is timed by the base query());
        a routed lane query transfers only that source's [N] pair."""
        self.drain()
        s = self.state.sssp
        dist, parent = (s.dist, s.parent) if lane is None else \
            (s.dist[lane], s.parent[lane])
        return (np.asarray(jax.device_get(dist)),
                np.asarray(jax.device_get(parent)))

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self) -> dict[str, np.ndarray]:
        """O(N+E) snapshot for fault tolerance (see train/checkpoint.py for
        the sharded writer used at scale).  Backend layout state is NOT
        serialized — it is a derived view, rebuilt from the pool on
        restore (the protocol's checkpoint-participation rule)."""
        with self.obs.epoch("checkpoint"):
            self.drain()   # a checkpoint must capture a converged tree
            e, s = self.state.edges, self.state.sssp
            return {
                "src": np.asarray(e.src), "dst": np.asarray(e.dst),
                "w": np.asarray(e.w), "active": np.asarray(e.active),
                "dist": np.asarray(s.dist), "parent": np.asarray(s.parent),
                "source": np.asarray(s.source),
                "cursor": np.asarray(self.state.cursor),
            }

    def restore(self, ckpt: dict[str, np.ndarray]) -> None:
        self.state = GraphState(
            edges=EdgePool(jnp.asarray(ckpt["src"]), jnp.asarray(ckpt["dst"]),
                           jnp.asarray(ckpt["w"]), jnp.asarray(ckpt["active"])),
            sssp=SSSPState(jnp.asarray(ckpt["dist"]), jnp.asarray(ckpt["parent"]),
                           jnp.asarray(ckpt["source"])),
            cursor=jnp.asarray(ckpt["cursor"]),
        )
        # rebuild host planner state (slot map + mirror) from the pool
        self.alloc = ingest.allocator_cls(self.cfg.alloc_impl).from_pool(
            self.cfg.edge_capacity, self.cfg.on_duplicate,
            ckpt["src"], ckpt["dst"], ckpt["w"], ckpt["active"])
        self.backend.restore(self.alloc)
        if self._sparse:
            self._out.restore(self.alloc)
        # the restore's layout rebuild is a real rebuild event (§10)
        self.obs.note_layout(self.backend.layout_counters())
        # checkpoints are taken post-drain, so nothing was pending
        self._pend = buckets.empty_pending(
            self.cfg.num_vertices,
            None if self.sources is None else len(self.sources))
        self._pend_bound = 0
