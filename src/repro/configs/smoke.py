"""Reduced-config smoke programs: real (tiny) arrays, runnable on one CPU
device.  Used by tests/test_arch_smoke.py and examples/quickstart.py.

Every assigned architecture gets: init -> one train step (forward+backward+
AdamW) -> metric dict, plus a decode step for the LM family.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry as reg
from repro.graphs import generators as gen
from repro.graphs import triplets as tri_mod
from repro.models import din as din_mod
from repro.models import transformer as tfm
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import steps as steps_mod


def _finite_tree(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating))


def smoke_lm(arch_id: str, seed: int = 0) -> dict:
    cfg = reg.ARCHES[arch_id].REDUCED
    key = jax.random.key(seed)
    params = tfm.init_lm(key, cfg)
    stream = data_mod.TokenStream(vocab_size=cfg.vocab_size, batch=2,
                                  seq_len=16, seed=seed)
    batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
    loss_fn = partial(tfm.lm_loss, cfg=cfg)
    step = jax.jit(steps_mod.make_train_step(
        loss_fn, opt_mod.AdamWConfig(warmup_steps=2, total_steps=10), 1))
    opt_state = opt_mod.adamw_init(params)
    params, opt_state, metrics = step(params, opt_state, batch)

    # decode: 3 tokens against a small cache
    cache = tfm.init_cache(cfg, batch=2, capacity=8)
    dec = jax.jit(partial(tfm.decode_step, cfg=cfg))
    logits = None
    for t in range(3):
        tok = jnp.asarray(np.full((2,), t + 1, np.int32))
        logits, cache = dec(params, cache, tok)
    assert logits.shape == (2, cfg.padded_vocab)
    metrics = dict(metrics)
    metrics["decode_finite"] = jnp.all(jnp.isfinite(
        logits.astype(jnp.float32)))
    return jax.device_get(metrics)


def _small_graph(seed=0, n=24, m=64):
    n, src, dst, w = gen.erdos_renyi(n, m, seed=seed)
    rng = np.random.default_rng(seed)
    d_in = 8
    return {
        "n": n, "src": src.astype(np.int32), "dst": dst.astype(np.int32),
        "feats": rng.normal(size=(n, d_in)).astype(np.float32),
        "pos": rng.normal(size=(n, 3)).astype(np.float32),
        "labels": rng.integers(0, 4, n).astype(np.int32),
        "label_mask": np.ones(n, bool),
        "edge_mask": np.ones(len(src), bool),
    }


def smoke_gnn(arch_id: str, seed: int = 0) -> dict:
    cfg = reg.ARCHES[arch_id].REDUCED
    node_loss, graph_loss, init_fn, needs_pos, needs_tri = reg._GNN_FNS[arch_id]
    g = _small_graph(seed)
    batch = {k: jnp.asarray(v) for k, v in g.items() if k != "n"}
    if needs_tri:
        t_kj, t_ji, tmask = tri_mod.build_triplets(
            g["n"], g["src"], g["dst"], budget=256, per_edge_cap=4, seed=seed)
        batch["t_kj"], batch["t_ji"] = jnp.asarray(t_kj), jnp.asarray(t_ji)
        batch["triplet_mask"] = jnp.asarray(tmask)
    params = init_fn(jax.random.key(seed), cfg)
    loss_fn = partial(reg._gnn_loss_call, loss=node_loss, cfg=cfg)
    step = jax.jit(steps_mod.make_train_step(
        loss_fn, opt_mod.AdamWConfig(warmup_steps=2, total_steps=10), 1))
    opt_state = opt_mod.adamw_init(params)
    params, opt_state, metrics = step(params, opt_state, batch)

    # batched-molecule path (vmapped forward + graph regression)
    B = 3
    gs = [_small_graph(seed + i, n=10, m=20) for i in range(B)]
    mol = {
        "feats": jnp.stack([g["feats"][:10] for g in gs]),
        "pos": jnp.stack([g["pos"][:10] for g in gs]),
        "src": jnp.stack([g["src"][:20] % 10 for g in gs]),
        "dst": jnp.stack([g["dst"][:20] % 10 for g in gs]),
        "edge_mask": jnp.stack([g["edge_mask"][:20] for g in gs]),
        "target": jnp.zeros((B,), jnp.float32),
    }
    if needs_tri:
        tk, tj, tm = [], [], []
        for i, g in enumerate(gs):
            a, b, m = tri_mod.build_triplets(
                10, np.asarray(mol["src"][i]), np.asarray(mol["dst"][i]),
                budget=64, per_edge_cap=4, seed=seed + i)
            tk.append(a); tj.append(b); tm.append(m)
        mol["t_kj"], mol["t_ji"] = jnp.asarray(np.stack(tk)), jnp.asarray(np.stack(tj))
        mol["triplet_mask"] = jnp.asarray(np.stack(tm))
    gl, gm = jax.jit(partial(reg._gnn_loss_call, loss=graph_loss, cfg=cfg))(
        params, mol)
    metrics = dict(metrics)
    metrics["mol_loss"] = gl
    return jax.device_get(metrics)


def smoke_din(seed: int = 0) -> dict:
    cfg = reg.ARCHES["din"].REDUCED
    stream = data_mod.ClickStream(n_items=cfg.n_items, n_cates=cfg.n_cates,
                                  batch=8, seq_len=cfg.seq_len, seed=seed)
    batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
    params = din_mod.init_din(jax.random.key(seed), cfg)
    loss_fn = partial(reg._din_loss_call, cfg=cfg)
    step = jax.jit(steps_mod.make_train_step(
        loss_fn, opt_mod.AdamWConfig(warmup_steps=2, total_steps=10), 1))
    opt_state = opt_mod.adamw_init(params)
    params, opt_state, metrics = step(params, opt_state, batch)
    # retrieval path
    rng = np.random.default_rng(seed)
    rbatch = {
        "hist_items": jnp.asarray(rng.integers(0, cfg.n_items, cfg.seq_len),
                                  jnp.int32),
        "hist_cates": jnp.asarray(rng.integers(0, cfg.n_cates, cfg.seq_len),
                                  jnp.int32),
        "hist_mask": jnp.ones((cfg.seq_len,), jnp.bool_),
        "cand_items": jnp.asarray(rng.integers(0, cfg.n_items, 64), jnp.int32),
        "cand_cates": jnp.asarray(rng.integers(0, cfg.n_cates, 64), jnp.int32),
    }
    scores = jax.jit(partial(din_mod.din_retrieval, cfg=cfg))(params, rbatch)
    metrics = dict(metrics)
    metrics["retrieval_mean"] = jnp.mean(scores)
    return jax.device_get(metrics)


def smoke(arch_id: str, seed: int = 0) -> dict:
    fam = reg.ARCHES[arch_id].FAMILY
    if fam == "lm":
        return smoke_lm(arch_id, seed)
    if fam == "gnn":
        return smoke_gnn(arch_id, seed)
    if fam == "recsys":
        return smoke_din(seed)
    raise ValueError(f"no smoke for family {fam} (sssp has its own tests)")
