"""equiformer-v2 [arXiv:2306.12059; unverified]: 12 layers, d_hidden=128,
l_max=6, m_max=2, 8 heads, SO(2)/eSCN-restricted equivariant attention."""
from repro.models.gnn.equiformer import EqV2Config

ARCH_ID = "equiformer-v2"
FAMILY = "gnn"

CONFIG = EqV2Config(n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8)
REDUCED = EqV2Config(n_layers=2, d_hidden=16, l_max=2, m_max=1, n_heads=2,
                     d_in=8, n_out=4)
