"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B; hf]: 48L
d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=163840,
MoE 64 experts top-6."""
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "moonshot-v1-16b-a3b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408),
    grad_accum=8,
    # §Perf D1 (refuted): batch-only residual sharding HURTS the MoE
    # dispatch (x +43%, peak +227% on train_4k) — keep GSPMD-chosen layouts
    act_batch_sharding=False,
)

REDUCED = LMConfig(
    name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=3, d_ff=48),
    grad_accum=1, vocab_pad_to=32,
)
