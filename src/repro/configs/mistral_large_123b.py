"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407; unverified]:
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768."""
from repro.models.transformer import LMConfig

ARCH_ID = "mistral-large-123b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_head=128, d_ff=28672, vocab_size=32768,
    grad_accum=8,    # 123B activation-memory lever; microbatch 32 divides
                     # the (pod, data) batch shards on both meshes
    # §Perf A3: two-level remat (11 groups x 8 layers) — peak 59->21 GB
    remat_policy="sqrt", remat_group=8,
)

REDUCED = LMConfig(
    name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_head=8, d_ff=160, vocab_size=256,
    grad_accum=1, vocab_pad_to=32,
)
