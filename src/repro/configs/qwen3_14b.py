"""qwen3-14b [hf:Qwen/Qwen3-8B family; hf]: 40L d_model=5120 40H (GQA kv=8)
d_ff=17408 vocab=151936, qk-norm."""
from repro.models.transformer import LMConfig

ARCH_ID = "qwen3-14b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_head=128, d_ff=17408, vocab_size=151936, qk_norm=True,
    grad_accum=8,
)

REDUCED = LMConfig(
    name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_head=8, d_ff=128, vocab_size=256, qk_norm=True,
    grad_accum=1, vocab_pad_to=32,
)
