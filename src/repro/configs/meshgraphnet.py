"""meshgraphnet [arXiv:2010.03409; unverified]: 15 MP layers, d_hidden=128,
sum aggregator, 2-layer MLPs."""
from repro.models.gnn.meshgraphnet import MGNConfig

ARCH_ID = "meshgraphnet"
FAMILY = "gnn"

CONFIG = MGNConfig(n_layers=15, d_hidden=128, mlp_layers=2, aggregator="sum")
REDUCED = MGNConfig(n_layers=2, d_hidden=16, mlp_layers=1, aggregator="sum",
                    d_in=8, n_out=4)
