"""dimenet [arXiv:2003.03123; unverified]: 6 blocks, d_hidden=128,
n_bilinear=8, n_spherical=7, n_radial=6."""
from repro.models.gnn.dimenet import DimeNetConfig

ARCH_ID = "dimenet"
FAMILY = "gnn"

CONFIG = DimeNetConfig(n_blocks=6, d_hidden=128, n_bilinear=8,
                       n_spherical=7, n_radial=6)
REDUCED = DimeNetConfig(n_blocks=2, d_hidden=16, n_bilinear=2,
                        n_spherical=3, n_radial=3, d_in=8, n_out=4)
