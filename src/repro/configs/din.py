"""din [arXiv:1706.06978; paper]: embed_dim=18, seq_len=100,
attention MLP 80-40, prediction MLP 200-80, target attention.

Tables: 10M items / 1K categories (taobao-scale item table; the embedding
LOOKUP is the hot path per the kernel taxonomy)."""
from repro.models.din import DINConfig

ARCH_ID = "din"
FAMILY = "recsys"

CONFIG = DINConfig(embed_dim=18, seq_len=100, attn_mlp=(80, 40),
                   mlp=(200, 80), n_items=10 * 1024 * 1024, n_cates=1_024)
REDUCED = DINConfig(embed_dim=8, seq_len=12, attn_mlp=(16, 8), mlp=(24, 12),
                    n_items=1_000, n_cates=16)
