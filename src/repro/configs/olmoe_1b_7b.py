"""olmoe-1b-7b [arXiv:2409.02060; hf]: 16L d_model=2048 16H (GQA kv=16)
d_ff=1024 (per expert) vocab=50304, MoE 64 experts top-8."""
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "olmoe-1b-7b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, qk_norm=True,  # OLMoE uses qk-norm
    moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024),
    grad_accum=8,
    # §Perf D1 (refuted): batch-only residual sharding HURTS the MoE
    # dispatch (x +43%, peak +227% on train_4k) — keep GSPMD-chosen layouts
    act_batch_sharding=False,
)

REDUCED = LMConfig(
    name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, qk_norm=True,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=32),
    grad_accum=1, vocab_pad_to=32,
)
