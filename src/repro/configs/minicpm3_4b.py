"""minicpm3-4b [hf:openbmb/MiniCPM3-4B; hf]: 62L d_model=2560 40H MLA
d_ff=6400 vocab=73448 (padded to 73472 for 16-way TP)."""
from repro.models.mla import MLAConfig
from repro.models.transformer import LMConfig

ARCH_ID = "minicpm3-4b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448, attn="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                  qk_rope_dim=32, v_head_dim=64),
    grad_accum=4,
)

REDUCED = LMConfig(
    name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, attn="mla",
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8,
                  qk_rope_dim=4, v_head_dim=8),
    grad_accum=1, vocab_pad_to=32,
)
