"""graphsage-reddit [arXiv:1706.02216; paper]: 2 layers, d_hidden=128, mean
aggregator, sample sizes 25-10 (shape minibatch_lg uses the assigned 15-10
fanout)."""
from repro.models.gnn.graphsage import SAGEConfig

ARCH_ID = "graphsage-reddit"
FAMILY = "gnn"

CONFIG = SAGEConfig(n_layers=2, d_hidden=128, sample_sizes=(25, 10))
REDUCED = SAGEConfig(n_layers=2, d_hidden=16, sample_sizes=(3, 2),
                     d_in=8, n_out=4)
