"""Architecture/shape registry: every (arch x input-shape) cell as a
lowerable program.

``build_program(arch, shape, mesh)`` returns a ``Program`` carrying the
step function, ShapeDtypeStruct inputs (no allocation), and the
in/out shardings for the production mesh — consumed by launch/dryrun.py,
the roofline analyzer, and the perf harness.

``build_smoke(arch)`` returns a runnable REDUCED-config program with real
(tiny) arrays for the per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from contextlib import nullcontext as _nullcontext
from functools import partial
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import (din as c_din, dimenet as c_dimenet,
                           equiformer_v2 as c_eqv2,
                           graphsage_reddit as c_sage,
                           meshgraphnet as c_mgn,
                           minicpm3_4b as c_minicpm,
                           mistral_large_123b as c_mistral,
                           moonshot_v1_16b_a3b as c_moonshot,
                           olmoe_1b_7b as c_olmoe,
                           qwen3_14b as c_qwen,
                           sssp_del as c_sssp)
from repro.models import din as din_mod
from repro.models import sharding as shd
from repro.models import transformer as tfm
from repro.models.gnn import (dimenet as dimenet_mod, equiformer as eqv2_mod,
                              graphsage as sage_mod,
                              meshgraphnet as mgn_mod)
from repro.train import optimizer as opt_mod
from repro.train import steps as steps_mod

ARCHES = {
    m.ARCH_ID: m for m in (
        c_olmoe, c_moonshot, c_minicpm, c_mistral, c_qwen,
        c_mgn, c_sage, c_dimenet, c_eqv2, c_din, c_sssp)
}

LM_SHAPES = {
    "train_4k":    dict(kind="train",   seq=4096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,  batch=32),
    "decode_32k":  dict(kind="decode",  seq=32768,  batch=128),
    "long_500k":   dict(kind="decode",  seq=524288, batch=1,
                        skip="pure full-attention arch: 500k decode is "
                             "sub-quadratic-only per the assignment"),
}
GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n=2708, e=10556, d_feat=1433,
                          classes=7),
    "minibatch_lg":  dict(kind="train", n_total=232_965, e_total=114_615_892,
                          batch_nodes=1024, fanout=(15, 10), d_feat=602,
                          classes=41),
    "ogb_products":  dict(kind="train", n=2_449_029, e=61_859_140,
                          d_feat=100, classes=47),
    "molecule":      dict(kind="train", n=30, e=64, batch=128, graph=True),
}
DIN_SHAPES = {
    "train_batch":    dict(kind="train", batch=65_536),
    "serve_p99":      dict(kind="serve", batch=512),
    "serve_bulk":     dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=1_000_000),
}
SSSP_SHAPES = {
    "relax_rmat24":  dict(kind="relax", n=1 << 24, epp=1 << 20),
    "delete_rmat24": dict(kind="delete", n=1 << 24, epp=1 << 20),
    "relax_web1b":   dict(kind="relax", n=1 << 26, epp=1 << 22),
    "delete_web1b":  dict(kind="delete", n=1 << 26, epp=1 << 22),
}

FAMILY_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": DIN_SHAPES,
                 "sssp": SSSP_SHAPES}

# padding unit that divides both production meshes (256 and 512 devices)
PAD = 512


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    kind: str
    skip: str | None = None


@dataclasses.dataclass
class Program:
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple
    meta: dict


def all_cells(include_sssp: bool = True) -> list[Cell]:
    cells = []
    for arch_id, mod in ARCHES.items():
        if mod.FAMILY == "sssp" and not include_sssp:
            continue
        for shape, info in FAMILY_SHAPES[mod.FAMILY].items():
            cells.append(Cell(arch=arch_id, shape=shape, kind=info["kind"],
                              skip=info.get("skip")))
    return cells


def _pad(n: int, m: int = PAD) -> int:
    return -(-n // m) * m


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _replicated_tree(tree, mesh):
    return jax.tree.map(lambda _: _ns(mesh, P()), tree)


# ===================================================================== LM ====

def _lm_cast(pshape, dtype):
    return jax.tree.map(lambda s: _sds(s.shape, dtype), pshape)


def _lm_train_program(cfg: tfm.LMConfig, mesh: Mesh, info) -> Program:
    pshape = tfm.lm_param_shapes(cfg)
    oshape = jax.eval_shape(opt_mod.adamw_init, pshape)
    pspec = shd.lm_param_specs(pshape, mesh)
    psh = jax.tree.map(lambda s: _ns(mesh, s), pspec,
                       is_leaf=lambda x: isinstance(x, P))
    osh = {"m": psh, "v": psh, "step": _ns(mesh, P())}
    bx = shd.batch_axes(mesh)
    A, B, S = cfg.grad_accum, info["batch"], info["seq"]
    mb = B // A
    if A > 1:
        batch = {"tokens": _sds((A, mb, S), jnp.int32),
                 "labels": _sds((A, mb, S), jnp.int32)}
        bsh = jax.tree.map(lambda _: _ns(mesh, P(None, bx, None)), batch)
    else:
        batch = {"tokens": _sds((B, S), jnp.int32),
                 "labels": _sds((B, S), jnp.int32)}
        bsh = jax.tree.map(lambda _: _ns(mesh, P(bx, None)), batch)

    loss_fn = partial(lm_loss_adapter, cfg=cfg)
    step = steps_mod.make_train_step(loss_fn, opt_mod.AdamWConfig(), A)
    step = _with_act_sharding(step, cfg, mesh)
    metrics_shape = jax.eval_shape(step, pshape, oshape, batch)[2]
    msh = _replicated_tree(metrics_shape, mesh)
    return Program(
        fn=step, args=(pshape, oshape, batch),
        in_shardings=(psh, osh, bsh),
        out_shardings=(psh, osh, msh),
        donate_argnums=(0, 1),
        meta={"model_flops": cfg.model_flops(B * S, train=True),
              "tokens": B * S, "params": cfg.param_count(),
              "active_params": cfg.active_param_count()})


def lm_loss_adapter(params, batch, cfg):
    return tfm.lm_loss(params, batch, cfg)


def _with_act_sharding(fn, cfg, mesh):
    """Trace ``fn`` under the activation-sharding context.  The context is
    always entered; the per-site constraints gate themselves (the residual
    constraint on cfg.act_batch_sharding — §Perf A2/D1)."""

    def wrapped(*args):
        with tfm.activation_sharding(mesh, shd.batch_axes(mesh)):
            return fn(*args)

    return wrapped


def _lm_prefill_program(cfg: tfm.LMConfig, mesh: Mesh, info) -> Program:
    pshape = _lm_cast(tfm.lm_param_shapes(cfg), jnp.bfloat16)
    pspec = shd.lm_param_specs(pshape, mesh)
    psh = jax.tree.map(lambda s: _ns(mesh, s), pspec,
                       is_leaf=lambda x: isinstance(x, P))
    bx = shd.batch_axes(mesh)
    B, S = info["batch"], info["seq"]
    tokens = _sds((B, S), jnp.int32)

    def prefill_fn(params, toks):
        # ctx always active: the cache-slice constraint (§Perf B1) applies
        # to every arch; the residual-stream constraint gates itself on
        # cfg.act_batch_sharding inside block_forward/prefill.
        with tfm.activation_sharding(mesh, shd.batch_axes(mesh)):
            logits, cache = tfm.prefill(params, toks, cfg, capacity=S)
        return logits[:, -1, :], cache

    cache_shape = tfm.cache_shapes(cfg, B, S)
    csp = shd.cache_spec(cache_shape, mesh)
    csh = jax.tree.map(lambda s: _ns(mesh, s), csp,
                       is_leaf=lambda x: isinstance(x, P))
    out_sh = (_ns(mesh, P(bx, None)), csh)
    n_act = cfg.active_param_count()
    return Program(
        fn=prefill_fn, args=(pshape, tokens),
        in_shardings=(psh, _ns(mesh, P(bx, None))),
        out_shardings=out_sh, donate_argnums=(),
        meta={"model_flops": cfg.model_flops(B * S, train=False),
              "tokens": B * S, "params": cfg.param_count(),
              "active_params": n_act})


def _lm_decode_program(cfg: tfm.LMConfig, mesh: Mesh, info) -> Program:
    pshape = _lm_cast(tfm.lm_param_shapes(cfg), jnp.bfloat16)
    pspec = shd.lm_param_specs(pshape, mesh)
    psh = jax.tree.map(lambda s: _ns(mesh, s), pspec,
                       is_leaf=lambda x: isinstance(x, P))
    bx = shd.batch_axes(mesh)
    B, S = info["batch"], info["seq"]
    cache_shape = tfm.cache_shapes(cfg, B, S)
    csp = shd.cache_spec(cache_shape, mesh)
    csh = jax.tree.map(lambda s: _ns(mesh, s), csp,
                       is_leaf=lambda x: isinstance(x, P))
    tokens = _sds((B,), jnp.int32)

    def decode_fn(params, cache, toks):
        return tfm.decode_step(params, cache, toks, cfg)

    out_sh = (_ns(mesh, P(bx, None)), csh)
    # decode FLOPs: 2*N_act per token + attention reads; it is memory-bound
    flops = cfg.model_flops(B, train=False)
    return Program(
        fn=decode_fn, args=(pshape, cache_shape, tokens),
        in_shardings=(psh, csh, _ns(mesh, P(bx))),
        out_shardings=out_sh, donate_argnums=(1,),
        meta={"model_flops": flops, "tokens": B,
              "params": cfg.param_count(),
              "active_params": cfg.active_param_count(),
              "kv_bytes": sum(np.prod(s.shape) * 2
                              for s in jax.tree.leaves(cache_shape)
                              if hasattr(s, "shape") and len(s.shape) > 0)})


# ==================================================================== GNN ====

_GNN_FNS = {
    "meshgraphnet": (mgn_mod.mgn_node_loss, mgn_mod.mgn_graph_loss,
                     mgn_mod.init_mgn, True, False),
    "graphsage-reddit": (sage_mod.sage_node_loss, sage_mod.sage_graph_loss,
                         sage_mod.init_sage, False, False),
    "dimenet": (dimenet_mod.dimenet_node_loss, dimenet_mod.dimenet_graph_loss,
                dimenet_mod.init_dimenet, True, True),
    "equiformer-v2": (eqv2_mod.eqv2_node_loss, eqv2_mod.eqv2_graph_loss,
                      eqv2_mod.init_eqv2, True, False),
}


def _gnn_resolve_cfg(arch_mod, info, reduced=False):
    cfg = arch_mod.REDUCED if reduced else arch_mod.CONFIG
    d_feat = info.get("d_feat", 16)
    classes = info.get("classes", cfg.n_out)
    if not reduced:
        cfg = dataclasses.replace(cfg, d_in=d_feat, n_out=classes)
    return cfg


def _gnn_flat_batch(info, d_feat, *, needs_pos, needs_tri) -> dict:
    if "n" in info:
        n, e = _pad(info["n"]), _pad(info["e"])
    else:  # minibatch_lg: padded sampled subgraph
        from repro.graphs import sampler as sampler_mod
        n0, e0 = sampler_mod.subgraph_capacity(info["batch_nodes"],
                                               info["fanout"])
        n, e = _pad(n0), _pad(e0)
    batch = {
        "feats": _sds((n, d_feat), jnp.float32),
        "src": _sds((e,), jnp.int32), "dst": _sds((e,), jnp.int32),
        "edge_mask": _sds((e,), jnp.bool_),
        "labels": _sds((n,), jnp.int32),
        "label_mask": _sds((n,), jnp.bool_),
    }
    if needs_pos:
        batch["pos"] = _sds((n, 3), jnp.float32)
    if needs_tri:
        from repro.graphs import triplets as tri_mod
        t = _pad(tri_mod.triplet_budget(e))
        batch["t_kj"] = _sds((t,), jnp.int32)
        batch["t_ji"] = _sds((t,), jnp.int32)
        batch["triplet_mask"] = _sds((t,), jnp.bool_)
    return batch


def _gnn_mol_batch(info, d_feat, *, needs_pos, needs_tri) -> dict:
    B, n, e = info["batch"], info["n"], info["e"]
    batch = {
        "feats": _sds((B, n, d_feat), jnp.float32),
        "src": _sds((B, e), jnp.int32), "dst": _sds((B, e), jnp.int32),
        "edge_mask": _sds((B, e), jnp.bool_),
        "target": _sds((B,), jnp.float32),
    }
    if needs_pos:
        batch["pos"] = _sds((B, n, 3), jnp.float32)
    if needs_tri:
        t = e * 8
        batch["t_kj"] = _sds((B, t), jnp.int32)
        batch["t_ji"] = _sds((B, t), jnp.int32)
        batch["triplet_mask"] = _sds((B, t), jnp.bool_)
    return batch


def _gnn_program(arch_id: str, mesh: Mesh, info) -> Program:
    arch_mod = ARCHES[arch_id]
    node_loss, graph_loss, init_fn, needs_pos, needs_tri = _GNN_FNS[arch_id]
    cfg = _gnn_resolve_cfg(arch_mod, info)
    pshape = jax.eval_shape(lambda: init_fn(jax.random.key(0), cfg))
    oshape = jax.eval_shape(opt_mod.adamw_init, pshape)
    psh = _replicated_tree(pshape, mesh)   # GNN params are small: replicate
    osh = {"m": _replicated_tree(pshape, mesh),
           "v": _replicated_tree(pshape, mesh), "step": _ns(mesh, P())}
    gx = shd.graph_axes(mesh)
    bx = shd.batch_axes(mesh)
    molecule = info.get("graph", False)
    d_feat = info.get("d_feat", 16)
    if molecule:
        batch = _gnn_mol_batch(info, d_feat, needs_pos=needs_pos,
                               needs_tri=needs_tri)
        bsh = jax.tree.map(
            lambda s: _ns(mesh, P(bx, *([None] * (len(s.shape) - 1)))), batch)
        loss_fn = partial(_gnn_loss_call, loss=graph_loss, cfg=cfg)
    else:
        batch = _gnn_flat_batch(info, d_feat, needs_pos=needs_pos,
                                needs_tri=needs_tri)
        bsh = jax.tree.map(
            lambda s: _ns(mesh, P(gx, *([None] * (len(s.shape) - 1)))), batch)
        loss_fn = partial(_gnn_loss_call, loss=node_loss, cfg=cfg)

    step = steps_mod.make_train_step(loss_fn, opt_mod.AdamWConfig(), 1)
    metrics_shape = jax.eval_shape(step, pshape, oshape, batch)[2]
    msh = _replicated_tree(metrics_shape, mesh)
    n_edges = int(np.prod(batch["src"].shape))
    return Program(
        fn=step, args=(pshape, oshape, batch),
        in_shardings=(psh, osh, bsh), out_shardings=(psh, osh, msh),
        donate_argnums=(0, 1),
        meta={"model_flops": _gnn_model_flops(arch_id, cfg, batch),
              "edges": n_edges,
              "params": sum(int(np.prod(s.shape))
                            for s in jax.tree.leaves(pshape))})


def _gnn_loss_call(params, batch, loss, cfg):
    return loss(params, batch, cfg)


def _gnn_model_flops(arch_id, cfg, batch) -> float:
    """Analytic 'useful' FLOPs (fwd+bwd = 3x fwd matmul FLOPs)."""
    E = float(np.prod(batch["src"].shape))
    N = float(np.prod(batch["feats"].shape[:-1]))
    d = cfg.d_hidden
    if arch_id == "meshgraphnet":
        per_layer = E * (3 * d * d + d * d) * 2 + N * (2 * d * d + d * d) * 2
        fwd = cfg.n_layers * per_layer
    elif arch_id == "graphsage-reddit":
        d_in = batch["feats"].shape[-1]
        fwd = N * 2 * (d_in * d + d_in * d) + N * 2 * (d * d * 2)
    elif arch_id == "dimenet":
        T = float(np.prod(batch["t_kj"].shape))
        fwd = cfg.n_blocks * (E * 6 * d * d * 2
                              + T * (cfg.n_bilinear * d * d) * 2)
    else:  # equiformer-v2
        nc, nl = cfg.n_coef, cfg.n_l
        n_pair = len(cfg.pair_index()[0])
        fwd = cfg.n_layers * (E * (nl + 4 * n_pair) * d * d * 2
                              + N * 2 * nc * d * d * 2)
    return 3.0 * fwd


# ==================================================================== DIN ====

def _din_param_shardings(pshape, mesh):
    gx = shd.graph_axes(mesh)

    def one(path, s):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if "item_emb" in name:
            return _ns(mesh, P(gx, None))
        return _ns(mesh, P())
    return jax.tree_util.tree_map_with_path(one, pshape)


def _din_batch(info, cfg: din_mod.DINConfig, kind):
    if kind == "retrieval":
        C = _pad(info["n_cand"])
        return {
            "hist_items": _sds((cfg.seq_len,), jnp.int32),
            "hist_cates": _sds((cfg.seq_len,), jnp.int32),
            "hist_mask": _sds((cfg.seq_len,), jnp.bool_),
            "cand_items": _sds((C,), jnp.int32),
            "cand_cates": _sds((C,), jnp.int32),
        }
    B = info["batch"]
    batch = {
        "target_item": _sds((B,), jnp.int32),
        "target_cate": _sds((B,), jnp.int32),
        "hist_items": _sds((B, cfg.seq_len), jnp.int32),
        "hist_cates": _sds((B, cfg.seq_len), jnp.int32),
        "hist_mask": _sds((B, cfg.seq_len), jnp.bool_),
    }
    if kind == "train":
        batch["labels"] = _sds((B,), jnp.float32)
    return batch


def _din_program(mesh: Mesh, info) -> Program:
    cfg = c_din.CONFIG
    kind = info["kind"]
    pshape = din_mod.din_param_shapes(cfg)
    psh = _din_param_shardings(pshape, mesh)
    gx = shd.graph_axes(mesh)
    batch = _din_batch(info, cfg, kind)

    if kind == "train":
        oshape = jax.eval_shape(opt_mod.adamw_init, pshape)
        osh = {"m": psh, "v": psh, "step": _ns(mesh, P())}
        bsh = jax.tree.map(
            lambda s: _ns(mesh, P(gx, *([None] * (len(s.shape) - 1)))), batch)
        loss_fn = partial(_din_loss_call, cfg=cfg)
        step = steps_mod.make_train_step(loss_fn, opt_mod.AdamWConfig(), 1)
        metrics_shape = jax.eval_shape(step, pshape, oshape, batch)[2]
        msh = _replicated_tree(metrics_shape, mesh)
        flops = _din_flops(cfg, info["batch"]) * 3
        return Program(fn=step, args=(pshape, oshape, batch),
                       in_shardings=(psh, osh, bsh),
                       out_shardings=(psh, osh, msh), donate_argnums=(0, 1),
                       meta={"model_flops": flops, "rows": info["batch"],
                             "params": cfg.n_items * cfg.embed_dim})
    if kind == "serve":
        bsh = jax.tree.map(
            lambda s: _ns(mesh, P(gx, *([None] * (len(s.shape) - 1)))), batch)
        fn = partial(_din_score_call, cfg=cfg)
        return Program(fn=fn, args=(pshape, batch),
                       in_shardings=(psh, bsh),
                       out_shardings=_ns(mesh, P(gx)), donate_argnums=(),
                       meta={"model_flops": _din_flops(cfg, info["batch"]),
                             "rows": info["batch"],
                             "params": cfg.n_items * cfg.embed_dim})
    # retrieval
    def rsh(s):
        if len(s.shape) == 1 and s.shape[0] >= PAD:
            return _ns(mesh, P(gx))
        return _ns(mesh, P())
    bsh = jax.tree.map(rsh, batch)
    fn = partial(_din_retrieval_call, cfg=cfg)
    C = batch["cand_items"].shape[0]
    return Program(fn=fn, args=(pshape, batch),
                   in_shardings=(psh, bsh), out_shardings=_ns(mesh, P(gx)),
                   donate_argnums=(),
                   meta={"model_flops": _din_flops(cfg, C), "rows": C,
                         "params": cfg.n_items * cfg.embed_dim})


def _din_loss_call(params, batch, cfg):
    return din_mod.din_loss(params, batch, cfg)


def _din_score_call(params, batch, cfg):
    return din_mod.din_score(params, batch, cfg)


def _din_retrieval_call(params, batch, cfg):
    return din_mod.din_retrieval(params, batch, cfg)


def _din_flops(cfg: din_mod.DINConfig, rows: int) -> float:
    di = cfg.d_item
    attn = 4 * di * cfg.attn_mlp[0] + cfg.attn_mlp[0] * cfg.attn_mlp[1]
    mlp = 3 * di * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1]
    return rows * 2.0 * (cfg.seq_len * attn + mlp)


# =================================================================== SSSP ====

def _sssp_program(mesh: Mesh, info, overrides: dict | None = None) -> Program:
    from repro.core.distributed import DistConfig, DistributedSSSP
    cfg0 = c_sssp.CONFIG
    if overrides:
        cfg0 = dataclasses.replace(cfg0, **overrides)
    axes = tuple(mesh.axis_names)
    dcfg = DistConfig(num_vertices=info["n"], edges_per_part=info["epp"],
                      mesh_axes=axes, exchange=cfg0.exchange,
                      delta_cap=cfg0.delta_cap)
    eng = DistributedSSSP(mesh, dcfg)
    P_ = eng.P
    E = P_ * info["epp"]
    vsh = _ns(mesh, P(axes))
    esh = vsh
    dist = _sds((info["n"],), jnp.float32)
    parent = _sds((info["n"],), jnp.int32)
    flag = _sds((info["n"],), jnp.bool_)
    esrc = _sds((E,), jnp.int32)
    edst = _sds((E,), jnp.int32)
    ew = _sds((E,), jnp.float32)
    eact = _sds((E,), jnp.bool_)
    if info["kind"] == "relax":
        fn = eng.make_relax_epoch()
    else:
        fn = eng.make_delete_epoch()
    args = (dist, parent, flag, esrc, edst, ew, eact)
    in_sh = (vsh, vsh, vsh, esh, esh, esh, esh)
    out_sh = (vsh, vsh, _ns(mesh, P()))
    # per-round useful work: one fused gather+add+segmin over E edges
    return Program(fn=fn, args=args, in_shardings=in_sh,
                   out_shardings=out_sh, donate_argnums=(),
                   meta={"model_flops": 2.0 * E, "edges": E,
                         "vertices": info["n"], "note":
                         "while_loop: terms reported per round"})


# ================================================================ dispatch ====

def build_program(arch_id: str, shape: str, mesh: Mesh,
                  overrides: dict | None = None) -> Program:
    """``overrides``: dataclasses.replace kwargs applied to the arch config
    (LM family only) — used by the dry-run/perf harness to pin the baseline
    (attn_impl='scan') vs optimized (attn_impl='flash_vjp') variants."""
    mod = ARCHES[arch_id]
    info = FAMILY_SHAPES[mod.FAMILY][shape]
    if info.get("skip"):
        raise ValueError(f"cell ({arch_id}, {shape}) is skipped: {info['skip']}")
    if mod.FAMILY == "lm":
        cfg = mod.CONFIG
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        if info["kind"] == "train":
            return _lm_train_program(cfg, mesh, info)
        if info["kind"] == "prefill":
            return _lm_prefill_program(cfg, mesh, info)
        return _lm_decode_program(cfg, mesh, info)
    if mod.FAMILY == "gnn":
        return _gnn_program(arch_id, mesh, info)
    if mod.FAMILY == "recsys":
        return _din_program(mesh, info)
    return _sssp_program(mesh, info, overrides)
