"""sssp-del — the paper's own technique as the 11th selectable config.

Shapes are (vertex count, per-partition edge capacity): the total edge pool
scales with the mesh (shared-nothing, paper §3).  ``rmat24`` matches the
paper's RMAT(20) scaled to pod size; ``web_1b`` is a web-Google-like graph
at 1B+ edges (the 1000+-node design point)."""
import dataclasses

ARCH_ID = "sssp-del"
FAMILY = "sssp"


@dataclasses.dataclass(frozen=True)
class SSSPArchConfig:
    name: str
    num_vertices: int
    edges_per_part: int
    exchange: str = "allgather"   # paper-faithful; "delta" = beyond-paper
    delta_cap: int = 4096
    # Relaxation backend — one RelaxBackend name for BOTH engines
    # (core/backends/, DESIGN.md §2, §6, §7): "segment" = COO scatter-min
    # (portable default); "ellpack" = dense gather + row-min over the
    # incrementally maintained ELLPACK block (the Pallas kernel's layout —
    # bounded-degree fast path); "sliced" = hub-aware hybrid
    # (per-slice-width ELL + overflow COO lane) for power-law in-degree
    # graphs.  The sharded engine runs the same backend per partition.
    relax_backend: str = "segment"
    ell_block_rows: int = 256
    ell_init_k: int = 8
    sliced_slice_rows: int = 256
    sliced_hub_k: int = 32
    sliced_init_k: int = 2

    def _backend_kw(self) -> dict:
        """Only forward knobs the selected backend accepts — construction
        validates that cross-backend knobs stay at their defaults."""
        kw = dict(relax_backend=self.relax_backend)
        if self.relax_backend == "ellpack":
            kw.update(ell_block_rows=self.ell_block_rows,
                      ell_init_k=self.ell_init_k)
        elif self.relax_backend == "sliced":
            kw.update(sliced_slice_rows=self.sliced_slice_rows,
                      sliced_hub_k=self.sliced_hub_k,
                      sliced_init_k=self.sliced_init_k)
        return kw

    def make_engine(self, *, edge_capacity: int | None = None,
                    source: int = 0,
                    sources: tuple[int, ...] | None = None,
                    partitions: int | None = None, mesh=None, **overrides):
        """Build a READY engine carrying this arch config's backend
        selection — the one entry point for both engines (DESIGN.md §11.5;
        lazy import keeps configs/ free of core dependencies).

        Single host by default; pass ``mesh=`` or ``partitions=`` for the
        sharded engine (its total pool defaults to this config's
        ``edges_per_part`` x P when ``edge_capacity`` is omitted).
        ``sources`` selects batched multi-source serving (DESIGN.md §8);
        ``source`` is then ignored."""
        from repro.core.factory import make_engine as _make
        kw = dict(self._backend_kw())
        if mesh is not None or partitions is not None:
            kw.update(exchange=self.exchange, delta_cap=self.delta_cap)
            if edge_capacity is None:
                P = partitions
                if P is None:
                    P = 1
                    for a in mesh.axis_names:
                        P *= mesh.shape[a]
                edge_capacity = self.edges_per_part * P
        elif edge_capacity is None:
            raise ValueError("edge_capacity is required for the "
                             "single-host engine")
        kw.update(overrides)
        return _make(num_vertices=self.num_vertices,
                     edge_capacity=edge_capacity, source=source,
                     sources=sources, partitions=partitions, mesh=mesh,
                     **kw)

    # -------------------------------------------------- deprecated shims
    # The config-object bridges predate core/factory.make_engine; they
    # remain as thin shims so downstream pins keep working one release.
    def engine_config(self, *, edge_capacity: int, source: int,
                      sources: tuple[int, ...] | None = None, **overrides):
        """Deprecated: use ``make_engine`` (returns a ready engine) or
        construct ``EngineConfig`` directly."""
        import warnings

        from repro.core.engine import EngineConfig
        warnings.warn("SSSPArchConfig.engine_config is deprecated; use "
                      "SSSPArchConfig.make_engine / repro.make_engine",
                      DeprecationWarning, stacklevel=2)
        kw = dict(num_vertices=self.num_vertices,
                  edge_capacity=edge_capacity, source=source,
                  sources=sources, **self._backend_kw())
        kw.update(overrides)
        return EngineConfig(**kw)

    def sharded_engine_config(self, *, source: int,
                              sources: tuple[int, ...] | None = None,
                              **overrides):
        """Deprecated: use ``make_engine(partitions=...)`` /
        ``make_engine(mesh=...)``."""
        import warnings

        from repro.core.dist_engine import ShardedEngineConfig
        warnings.warn("SSSPArchConfig.sharded_engine_config is deprecated; "
                      "use SSSPArchConfig.make_engine / repro.make_engine",
                      DeprecationWarning, stacklevel=2)
        kw = dict(num_vertices=self.num_vertices,
                  edges_per_part=self.edges_per_part, source=source,
                  exchange=self.exchange, delta_cap=self.delta_cap,
                  sources=sources, **self._backend_kw())
        kw.update(overrides)
        return ShardedEngineConfig(**kw)


CONFIG = SSSPArchConfig(name=ARCH_ID, num_vertices=1 << 24,
                        edges_per_part=1 << 20)
REDUCED = SSSPArchConfig(name=ARCH_ID + "-smoke", num_vertices=1 << 10,
                         edges_per_part=1 << 12)
