"""sssp-del — the paper's own technique as the 11th selectable config.

Shapes are (vertex count, per-partition edge capacity): the total edge pool
scales with the mesh (shared-nothing, paper §3).  ``rmat24`` matches the
paper's RMAT(20) scaled to pod size; ``web_1b`` is a web-Google-like graph
at 1B+ edges (the 1000+-node design point)."""
import dataclasses

ARCH_ID = "sssp-del"
FAMILY = "sssp"


@dataclasses.dataclass(frozen=True)
class SSSPArchConfig:
    name: str
    num_vertices: int
    edges_per_part: int
    exchange: str = "allgather"   # paper-faithful; "delta" = beyond-paper
    delta_cap: int = 4096


CONFIG = SSSPArchConfig(name=ARCH_ID, num_vertices=1 << 24,
                        edges_per_part=1 << 20)
REDUCED = SSSPArchConfig(name=ARCH_ID + "-smoke", num_vertices=1 << 10,
                         edges_per_part=1 << 12)
