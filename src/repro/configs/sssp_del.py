"""sssp-del — the paper's own technique as the 11th selectable config.

Shapes are (vertex count, per-partition edge capacity): the total edge pool
scales with the mesh (shared-nothing, paper §3).  ``rmat24`` matches the
paper's RMAT(20) scaled to pod size; ``web_1b`` is a web-Google-like graph
at 1B+ edges (the 1000+-node design point)."""
import dataclasses

ARCH_ID = "sssp-del"
FAMILY = "sssp"


@dataclasses.dataclass(frozen=True)
class SSSPArchConfig:
    name: str
    num_vertices: int
    edges_per_part: int
    exchange: str = "allgather"   # paper-faithful; "delta" = beyond-paper
    delta_cap: int = 4096
    # Relaxation backend — one RelaxBackend name for BOTH engines
    # (core/backends/, DESIGN.md §2, §6, §7): "segment" = COO scatter-min
    # (portable default); "ellpack" = dense gather + row-min over the
    # incrementally maintained ELLPACK block (the Pallas kernel's layout —
    # bounded-degree fast path); "sliced" = hub-aware hybrid
    # (per-slice-width ELL + overflow COO lane) for power-law in-degree
    # graphs.  The sharded engine runs the same backend per partition.
    relax_backend: str = "segment"
    ell_block_rows: int = 256
    ell_init_k: int = 8
    sliced_slice_rows: int = 256
    sliced_hub_k: int = 32
    sliced_init_k: int = 2

    def _backend_kw(self) -> dict:
        """Only forward knobs the selected backend accepts — construction
        validates that cross-backend knobs stay at their defaults."""
        kw = dict(relax_backend=self.relax_backend)
        if self.relax_backend == "ellpack":
            kw.update(ell_block_rows=self.ell_block_rows,
                      ell_init_k=self.ell_init_k)
        elif self.relax_backend == "sliced":
            kw.update(sliced_slice_rows=self.sliced_slice_rows,
                      sliced_hub_k=self.sliced_hub_k,
                      sliced_init_k=self.sliced_init_k)
        return kw

    def engine_config(self, *, edge_capacity: int, source: int,
                      sources: tuple[int, ...] | None = None, **overrides):
        """Bridge to the single-host engine: an ``EngineConfig`` carrying
        this arch config's backend selection (lazy import keeps configs/
        free of core dependencies).  ``sources`` selects the serving
        layer's batched multi-source mode (DESIGN.md §8): S stacked trees
        over one shared layout, ``source`` then ignored."""
        from repro.core.engine import EngineConfig
        kw = dict(num_vertices=self.num_vertices,
                  edge_capacity=edge_capacity, source=source,
                  sources=sources, **self._backend_kw())
        kw.update(overrides)
        return EngineConfig(**kw)

    def sharded_engine_config(self, *, source: int,
                              sources: tuple[int, ...] | None = None,
                              **overrides):
        """Bridge to the sharded engine: a ``ShardedEngineConfig`` carrying
        this arch config's backend selection, exchange strategy and
        per-partition pool capacity.  ``sources`` selects batched
        multi-source serving (DESIGN.md §8), same as ``engine_config``."""
        from repro.core.dist_engine import ShardedEngineConfig
        kw = dict(num_vertices=self.num_vertices,
                  edges_per_part=self.edges_per_part, source=source,
                  exchange=self.exchange, delta_cap=self.delta_cap,
                  sources=sources, **self._backend_kw())
        kw.update(overrides)
        return ShardedEngineConfig(**kw)


CONFIG = SSSPArchConfig(name=ARCH_ID, num_vertices=1 << 24,
                        edges_per_part=1 << 20)
REDUCED = SSSPArchConfig(name=ARCH_ID + "-smoke", num_vertices=1 << 10,
                         edges_per_part=1 << 12)
