"""Span tracer (DESIGN.md §10.2): monotonic host-side spans over the
engines' epoch dispatches, exportable as Chrome trace-event JSON (loads
directly in Perfetto / chrome://tracing) and as JSONL.

A span wraps one host-side dispatch region — add/del epoch, drain,
checkpoint, query — with ``time.perf_counter_ns`` stamps; when
``jax.profiler`` is importable each span also opens a
``TraceAnnotation`` so the same names land in XLA profiler traces.
Instant events mark point occurrences (layout rebuilds).  Nothing here
touches device values: the tracer is pure host bookkeeping, so it obeys
the §2.4 no-host-sync rule by construction (the device work inside a
span stays async; the span measures dispatch wall time, which is the
quantity the ingest loop actually spends).
"""
from __future__ import annotations

import dataclasses
import json
import time
from contextlib import contextmanager
from typing import Any, Iterator

try:  # TraceAnnotation exists across our supported jax range; stay soft
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - profiler missing from a slim build
    _TraceAnnotation = None

__all__ = ["Span", "SpanTracer", "load_chrome_trace", "span_counts_of"]


@dataclasses.dataclass
class Span:
    name: str
    t0_ns: int      # perf_counter_ns at entry (exit for instants)
    dur_ns: int     # 0 for instant events
    depth: int      # nesting depth at entry (0 = top-level)
    phase: str      # "X" complete span | "i" instant
    args: dict[str, Any] = dataclasses.field(default_factory=dict)


class SpanTracer:
    def __init__(self, enabled: bool = True, annotate: bool | None = None):
        self.enabled = enabled
        self._annotate = (_TraceAnnotation is not None if annotate is None
                          else bool(annotate) and _TraceAnnotation is not None)
        self._base_ns = time.perf_counter_ns()
        self._depth = 0
        self.spans: list[Span] = []   # completion order

    @contextmanager
    def span(self, name: str, **args) -> Iterator[None]:
        if not self.enabled:
            yield
            return
        depth = self._depth
        self._depth += 1
        ann = _TraceAnnotation(name) if self._annotate else None
        t0 = time.perf_counter_ns()
        try:
            if ann is not None:
                with ann:
                    yield
            else:
                yield
        finally:
            self._depth = depth
            self.spans.append(Span(name, t0, time.perf_counter_ns() - t0,
                                   depth, "X", args))

    def instant(self, name: str, **args) -> None:
        if not self.enabled:
            return
        self.spans.append(Span(name, time.perf_counter_ns(), 0,
                               self._depth, "i", args))

    # --------------------------------------------------------------- readout
    def span_counts(self) -> dict[str, int]:
        """Completed spans + instants by name (the figure the acceptance
        check matches against the engine's epoch/drain/rebuild counters)."""
        counts: dict[str, int] = {}
        for s in self.spans:
            counts[s.name] = counts.get(s.name, 0) + 1
        return counts

    # --------------------------------------------------------------- exports
    def to_chrome(self) -> dict[str, Any]:
        """Chrome trace-event JSON object ({"traceEvents": [...]}, ts/dur
        in microseconds) — loads as-is in Perfetto."""
        events = []
        for s in self.spans:
            e: dict[str, Any] = {
                "name": s.name, "cat": "engine", "ph": s.phase,
                "ts": (s.t0_ns - self._base_ns) / 1e3,
                "pid": 0, "tid": 0,
                "args": {"depth": s.depth, **s.args},
            }
            if s.phase == "X":
                e["dur"] = s.dur_ns / 1e3
            else:
                e["s"] = "t"
            events.append(e)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def jsonl_lines(self) -> list[str]:
        return [json.dumps({
            "name": s.name, "ph": s.phase, "depth": s.depth,
            "ts_us": (s.t0_ns - self._base_ns) / 1e3,
            "dur_us": s.dur_ns / 1e3, **({"args": s.args} if s.args else {}),
        }) for s in self.spans]

    def save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write("\n".join(self.jsonl_lines()) + "\n")


def load_chrome_trace(path: str) -> list[dict[str, Any]]:
    """Load a Chrome trace-event file back to its event list (round-trip
    validation for ``save_chrome`` outputs)."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path} is not a Chrome trace-event file "
                         f"(no 'traceEvents' key)")
    return doc["traceEvents"]


def span_counts_of(events: list[dict[str, Any]]) -> dict[str, int]:
    """Event counts by name over a loaded Chrome trace (complete spans and
    instants; metadata events are ignored)."""
    counts: dict[str, int] = {}
    for e in events:
        if e.get("ph") in ("X", "i"):
            counts[e["name"]] = counts.get(e["name"], 0) + 1
    return counts
