"""Device-side counter registry (DESIGN.md §10.1, §10.5).

Extends the §2.4 lazy-stats discipline from two hardwired scalars
(rounds, messages) to an open set of named counters.  Two kinds live in
one registry:

  * **device counters** — ``add(name, value)`` folds a device scalar (or
    an ``[S]`` per-lane / ``[P]`` per-partition vector) into a lazily
    accumulated device array with a plain ``+``: no host sync, no new
    collectives — the value is whatever the epoch already computed or a
    cheap eager reduction over state the engine already holds.  ``peak``
    folds with ``maximum`` instead (high-water marks).
  * **host counters** — ``inc(name, n)`` for numbers that are born on the
    host (planned batch sizes, planner rebuild totals, per-partition numpy
    tallies); ``n`` may be an int or a numpy array and accumulates by
    ``+`` as well.

Vector counters carry an optional **dimension** tag (§10.5): passing
``dim="partition"`` / ``dim="lane"`` on a write names the axis the vector
indexes, and ``attribution()`` groups the snapshot's tagged counters by
dimension — the per-partition / per-lane attribution surface of
``metrics_snapshot()``.  The tag is pure metadata (a host-side dict
entry); the fold itself is unchanged.

``snapshot()`` is the ONLY read-back point: one ``jax.device_get`` over
the whole device dict (query/checkpoint/report time), mirroring how
``n_rounds`` drains ``_dev_rounds``.  A disabled registry no-ops every
write so the instrumented ingest path stays on the §10.4 overhead
contract.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

__all__ = ["CounterRegistry"]


class CounterRegistry:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._dev: dict[str, jax.Array] = {}
        self._host: dict[str, Any] = {}
        self._dims: dict[str, str] = {}

    # ------------------------------------------------------- device counters
    def add(self, name: str, value, dim: str | None = None) -> None:
        """Lazily accumulate a device value — shape-agnostic (scalar, [S]
        per-lane, [P] per-partition); never blocks on the device."""
        if not self.enabled:
            return
        if dim is not None:
            self._dims[name] = dim
        cur = self._dev.get(name)
        self._dev[name] = value if cur is None else cur + value

    def peak(self, name: str, value, dim: str | None = None) -> None:
        """High-water-mark fold of a device value (elementwise maximum)."""
        if not self.enabled:
            return
        if dim is not None:
            self._dims[name] = dim
        cur = self._dev.get(name)
        self._dev[name] = value if cur is None else np.maximum(cur, value) \
            if isinstance(cur, np.ndarray) else jax.numpy.maximum(cur, value)

    # --------------------------------------------------------- host counters
    def inc(self, name: str, n=1, dim: str | None = None) -> None:
        """Host-side accumulate; ``n`` may be an int or a numpy array (e.g.
        a [P] per-partition tally) — both fold with ``+``."""
        if not self.enabled:
            return
        if dim is not None:
            self._dims[name] = dim
        self._host[name] = self._host.get(name, 0) + n

    # --------------------------------------------------------------- readout
    def names(self) -> list[str]:
        return sorted(set(self._host) | set(self._dev))

    def dims(self) -> dict[str, str]:
        """Copy of the name -> dimension tag map (§10.5)."""
        return dict(self._dims)

    def snapshot(self) -> dict[str, Any]:
        """Drain every counter to host values — ONE ``device_get`` over the
        device dict (the §2.4 read-back point); ints for scalars, numpy
        arrays for vector counters."""
        out: dict[str, Any] = {
            k: (int(v) if np.ndim(v) == 0 else np.asarray(v))
            for k, v in self._host.items()}
        if self._dev:
            for k, v in jax.device_get(self._dev).items():
                got = int(v) if np.ndim(v) == 0 else np.asarray(v)
                out[k] = out[k] + got if k in out else got
        return out

    def attribution(self, snap: dict[str, Any] | None = None
                    ) -> dict[str, dict[str, Any]]:
        """Group a snapshot's dimension-tagged counters by dimension:
        ``{"partition": {"adds_per_part": [P] array, ...},
           "lane": {"queries_per_lane": [S] array, ...}}``.
        Pass the snapshot already taken for this readout to avoid a second
        device_get; with ``snap=None`` one is taken here."""
        if not self._dims:
            return {}
        if snap is None:
            snap = self.snapshot()
        out: dict[str, dict[str, Any]] = {}
        for name, dim in self._dims.items():
            if name in snap:
                out.setdefault(dim, {})[name] = snap[name]
        return out
