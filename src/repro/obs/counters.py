"""Device-side counter registry (DESIGN.md §10.1).

Extends the §2.4 lazy-stats discipline from two hardwired scalars
(rounds, messages) to an open set of named counters.  Two kinds live in
one registry:

  * **device counters** — ``add(name, value)`` folds a device scalar (or
    an ``[S]`` per-lane / ``[P]`` per-partition vector) into a lazily
    accumulated device array with a plain ``+``: no host sync, no new
    collectives — the value is whatever the epoch already computed or a
    cheap eager reduction over state the engine already holds.  ``peak``
    folds with ``maximum`` instead (high-water marks).
  * **host counters** — ``inc(name, n)`` for numbers that are born on the
    host (planned batch sizes, planner rebuild totals, per-partition numpy
    tallies); ``n`` may be an int or a numpy array and accumulates by
    ``+`` as well.

``snapshot()`` is the ONLY read-back point: one ``jax.device_get`` over
the whole device dict (query/checkpoint/report time), mirroring how
``n_rounds`` drains ``_dev_rounds``.  A disabled registry no-ops every
write so the instrumented ingest path stays on the §10.4 overhead
contract.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

__all__ = ["CounterRegistry"]


class CounterRegistry:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._dev: dict[str, jax.Array] = {}
        self._host: dict[str, Any] = {}

    # ------------------------------------------------------- device counters
    def add(self, name: str, value) -> None:
        """Lazily accumulate a device value — shape-agnostic (scalar, [S]
        per-lane, [P] per-partition); never blocks on the device."""
        if not self.enabled:
            return
        cur = self._dev.get(name)
        self._dev[name] = value if cur is None else cur + value

    def peak(self, name: str, value) -> None:
        """High-water-mark fold of a device value (elementwise maximum)."""
        if not self.enabled:
            return
        cur = self._dev.get(name)
        self._dev[name] = value if cur is None else np.maximum(cur, value) \
            if isinstance(cur, np.ndarray) else jax.numpy.maximum(cur, value)

    # --------------------------------------------------------- host counters
    def inc(self, name: str, n=1) -> None:
        """Host-side accumulate; ``n`` may be an int or a numpy array (e.g.
        a [P] per-partition tally) — both fold with ``+``."""
        if not self.enabled:
            return
        self._host[name] = self._host.get(name, 0) + n

    # --------------------------------------------------------------- readout
    def names(self) -> list[str]:
        return sorted(set(self._host) | set(self._dev))

    def snapshot(self) -> dict[str, Any]:
        """Drain every counter to host values — ONE ``device_get`` over the
        device dict (the §2.4 read-back point); ints for scalars, numpy
        arrays for vector counters."""
        out: dict[str, Any] = {
            k: (int(v) if np.ndim(v) == 0 else np.asarray(v))
            for k, v in self._host.items()}
        if self._dev:
            for k, v in jax.device_get(self._dev).items():
                got = int(v) if np.ndim(v) == 0 else np.asarray(v)
                out[k] = out[k] + got if k in out else got
        return out
