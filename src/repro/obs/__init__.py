"""Engine observability layer (DESIGN.md §10): device-side counter
registry, span tracing with Perfetto export, and a per-epoch flight
recorder — shared by both engines via ``StreamEngineBase``.

``EngineObs`` bundles the three pieces behind one facade the engines
drive:

  * ``with obs.epoch(kind, **attrs):`` wraps one dispatched epoch — it
    opens a tracer span (plus the jax.profiler TraceAnnotation), bumps
    the matching host counter (``add_epoch`` -> ``add_epochs``), appends
    a flight-recorder record with the dispatch wall time, and on an
    escaping exception dumps the flight recorder ONCE before re-raising.
  * ``obs.note_layout(totals)`` diffs the backend's monotone layout
    totals (``RelaxBackend.layout_counters()``: rebuilds, overflow-lane
    hits) against the last observation, folding the deltas into counters
    and emitting one ``rebuild`` instant event per rebuild — so the span
    stream and the counter registry can never disagree (they are derived
    from the same deltas).  Totals may reset when the "auto" backend
    swaps layouts; negative deltas clamp to zero.
  * ``obs.counters`` / ``obs.tracer`` / ``obs.recorder`` for direct use
    (device-value accumulation, instants, extra records).
  * ``obs.hist_device(name, value)`` / ``obs.hist_cumulative(name, value)``
    / ``obs.hist_host(name, value)`` record histogram samples (§10.6).
    The device variants are ZERO-dispatch on the hot path: they append
    the device value (a per-epoch sample, or the engine's cumulative
    counter whose consecutive diffs are the samples) to a host-side
    list; ``flush_histograms()`` — called by ``metrics_snapshot()`` —
    materializes each list in a few stacked one-hot folds that ride the
    registry's lazy ``+`` and its single ``snapshot()`` device_get.
    Host samples (query latency) fold as numpy vectors immediately;
    device and host counts merge under the same ``hist_*`` name.
  * an optional :class:`~repro.obs.watchdog.Watchdog` (§10.8) armed
    around every ``epoch()`` region: stalls fire a structured warning +
    the one-shot dump from a sampler thread, slow-epoch/frontier
    thresholds are checked synchronously after each epoch.

Disabled (the default) every hook no-ops; the ``obs_overhead`` bench +
``check_regression`` gate hold instrumented ingest >= 0.95x
uninstrumented (§10.4).
"""
from __future__ import annotations

import os
import sys
import time
from contextlib import contextmanager
from typing import Any, Iterator

from repro.obs import hist as hist_mod
from repro.obs.counters import CounterRegistry
from repro.obs.recorder import FlightRecorder
from repro.obs.spans import (Span, SpanTracer, load_chrome_trace,
                             span_counts_of)
from repro.obs.watchdog import Watchdog, WatchdogConfig

__all__ = [
    "CounterRegistry", "EngineObs", "FlightRecorder", "Span", "SpanTracer",
    "Watchdog", "WatchdogConfig", "load_chrome_trace", "out_path_or_exit",
    "span_counts_of", "write_log_jsonl",
]

# span kind -> counter name: every epoch span bumps its counter from the
# SAME code path, which is what makes span counts and counters bit-consistent
_PLURAL = {
    "add_epoch": "add_epochs",
    "del_epoch": "del_epochs",
    "drain": "drains",
    "query": "queries",
    "checkpoint": "checkpoints",
}


class EngineObs:
    def __init__(self, enabled: bool = False, flight_capacity: int = 128,
                 watchdog: WatchdogConfig | None = None):
        self.enabled = bool(enabled)
        self.counters = CounterRegistry(self.enabled)
        self.tracer = SpanTracer(self.enabled)
        self.recorder = FlightRecorder(flight_capacity)
        self.watchdog = (Watchdog(watchdog, self)
                         if (self.enabled and watchdog is not None) else None)
        self._layout_last: dict[str, int] = {}
        # pending device histogram samples (§10.6): plain host lists of
        # device values — appending costs no device dispatch; materialized
        # by flush_histograms() at snapshot time
        self._hist_samples: dict[str, list] = {}
        self._hist_cum: dict[str, list] = {}
        self._hist_base: dict[str, Any] = {}
        self._dumped = False

    @contextmanager
    def epoch(self, kind: str, **attrs) -> Iterator[None]:
        if not self.enabled:
            yield
            return
        wd = self.watchdog
        t0 = time.perf_counter()
        if wd is not None:
            wd.arm(kind)
        try:
            with self.tracer.span(kind, **attrs):
                yield
        except BaseException as exc:
            self.recorder.record(kind, error=repr(exc), **attrs)
            self.dump_on_error(exc)
            raise
        finally:
            if wd is not None:
                wd.disarm()
        wall = time.perf_counter() - t0
        self.counters.inc(_PLURAL.get(kind, kind + "s"))
        self.recorder.record(kind, wall_ms=round(wall * 1e3, 3), **attrs)
        # per-kind dispatch wall-time histogram (§10.6): sample count per
        # kind equals the kind's counter by construction
        self.hist_host(f"hist_{kind}_wall_us", wall * 1e6)
        if wd is not None:
            wd.observe(kind, wall, attrs)

    # ------------------------------------------------------------- histograms
    def hist_device(self, name: str, value) -> None:
        """Record one device histogram sample (scalar, or [S] vector -> S
        samples) for counter ``name`` — a host-side list append, zero
        device dispatches on the hot path (§10.6/§10.4); the one-hot folds
        happen in flush_histograms()."""
        if self.enabled:
            self._hist_samples.setdefault(name, []).append(value)

    def hist_cumulative(self, name: str, value) -> None:
        """Record the engine's CUMULATIVE device counter after an epoch;
        consecutive diffs of the recorded series are the per-epoch samples
        (materialized at flush).  For engines whose epochs return updated
        cumulative counters rather than per-epoch stats — appending the
        returned array reference costs nothing."""
        if self.enabled:
            self._hist_cum.setdefault(name, []).append(value)

    def flush_histograms(self) -> None:
        """Materialize the pending sample lists into ``hist_*`` counters:
        a few stacked one-hot scatters per histogram (chunked so a long
        uninspected run cannot build an unboundedly wide stack op), folded
        through the registry's lazy ``+`` — no device_get here; the
        read-back stays ``snapshot()``'s single one."""
        if not self.enabled or not (self._hist_samples or self._hist_cum):
            return
        import jax.numpy as jnp
        CHUNK = 512
        for name, samples in self._hist_samples.items():
            for i in range(0, len(samples), CHUNK):
                vals = jnp.stack(
                    [jnp.asarray(s) for s in samples[i:i + CHUNK]])
                self.counters.add(name, hist_mod.one_hot(vals))
        self._hist_samples.clear()
        for name, series in self._hist_cum.items():
            if not series:
                continue
            base = self._hist_base.get(name)
            if base is None:
                base = jnp.zeros_like(jnp.asarray(series[0]))
            full = [base] + series
            for i in range(0, len(series), CHUNK):
                seg = jnp.stack(
                    [jnp.asarray(s) for s in full[i:i + CHUNK + 1]])
                self.counters.add(name, hist_mod.one_hot(seg[1:] - seg[:-1]))
            self._hist_base[name] = series[-1]
            series.clear()

    def hist_host(self, name: str, value: float) -> None:
        """Fold one host-born histogram sample (e.g. wall-clock latency in
        microseconds) into counter ``name`` as a numpy one-hot vector."""
        if self.enabled:
            self.counters.inc(name, hist_mod.one_hot_np(value))

    def note_layout(self, totals: dict[str, int]) -> None:
        """Fold the backend's monotone layout totals (rebuilds,
        overflow_hits, ...) into counters by delta; one ``rebuild``
        instant event per rebuild delta."""
        if not self.enabled:
            return
        for name, total in totals.items():
            delta = max(0, int(total) - self._layout_last.get(name, 0))
            self._layout_last[name] = int(total)
            if delta == 0:
                continue
            self.counters.inc(name, delta)
            if name == "rebuilds":
                for _ in range(delta):
                    self.tracer.instant("rebuild")

    def dump_on_error(self, exc: BaseException) -> None:
        """One-shot flight-recorder postmortem (nested epochs dump once)."""
        if self._dumped:
            return
        self._dumped = True
        self.recorder.dump(
            header=f"flight recorder postmortem "
                   f"({self.recorder.total} records total): {exc!r}")


# ----------------------------------------------------------- CLI plumbing --
def out_path_or_exit(path: str) -> str:
    """Validate a --trace-out / --log-json destination up front: a missing
    parent directory exits 2 (usage error) before any engine work runs."""
    parent = os.path.dirname(os.path.abspath(path))
    if not os.path.isdir(parent):
        print(f"error: output parent directory does not exist: {parent}",
              file=sys.stderr)
        raise SystemExit(2)
    return path


def _jsonable(v: Any) -> Any:
    import numpy as np
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    return v


def write_log_jsonl(engine, path: str) -> None:
    """JSONL export (--log-json): every span line followed by one final
    ``metrics_snapshot`` line — the machine-readable twin of --trace-out."""
    import json
    lines = engine.obs.tracer.jsonl_lines()
    lines.append(json.dumps(
        {"kind": "metrics_snapshot", **_jsonable(engine.metrics_snapshot())}))
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
