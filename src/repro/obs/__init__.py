"""Engine observability layer (DESIGN.md §10): device-side counter
registry, span tracing with Perfetto export, and a per-epoch flight
recorder — shared by both engines via ``StreamEngineBase``.

``EngineObs`` bundles the three pieces behind one facade the engines
drive:

  * ``with obs.epoch(kind, **attrs):`` wraps one dispatched epoch — it
    opens a tracer span (plus the jax.profiler TraceAnnotation), bumps
    the matching host counter (``add_epoch`` -> ``add_epochs``), appends
    a flight-recorder record with the dispatch wall time, and on an
    escaping exception dumps the flight recorder ONCE before re-raising.
  * ``obs.note_layout(totals)`` diffs the backend's monotone layout
    totals (``RelaxBackend.layout_counters()``: rebuilds, overflow-lane
    hits) against the last observation, folding the deltas into counters
    and emitting one ``rebuild`` instant event per rebuild — so the span
    stream and the counter registry can never disagree (they are derived
    from the same deltas).  Totals may reset when the "auto" backend
    swaps layouts; negative deltas clamp to zero.
  * ``obs.counters`` / ``obs.tracer`` / ``obs.recorder`` for direct use
    (device-value accumulation, instants, extra records).

Disabled (the default) every hook no-ops; the ``obs_overhead`` bench +
``check_regression`` gate hold instrumented ingest >= 0.95x
uninstrumented (§10.4).
"""
from __future__ import annotations

import os
import sys
import time
from contextlib import contextmanager
from typing import Any, Iterator

from repro.obs.counters import CounterRegistry
from repro.obs.recorder import FlightRecorder
from repro.obs.spans import (Span, SpanTracer, load_chrome_trace,
                             span_counts_of)

__all__ = [
    "CounterRegistry", "EngineObs", "FlightRecorder", "Span", "SpanTracer",
    "load_chrome_trace", "out_path_or_exit", "span_counts_of",
    "write_log_jsonl",
]

# span kind -> counter name: every epoch span bumps its counter from the
# SAME code path, which is what makes span counts and counters bit-consistent
_PLURAL = {
    "add_epoch": "add_epochs",
    "del_epoch": "del_epochs",
    "drain": "drains",
    "query": "queries",
    "checkpoint": "checkpoints",
}


class EngineObs:
    def __init__(self, enabled: bool = False, flight_capacity: int = 128):
        self.enabled = bool(enabled)
        self.counters = CounterRegistry(self.enabled)
        self.tracer = SpanTracer(self.enabled)
        self.recorder = FlightRecorder(flight_capacity)
        self._layout_last: dict[str, int] = {}
        self._dumped = False

    @contextmanager
    def epoch(self, kind: str, **attrs) -> Iterator[None]:
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter()
        try:
            with self.tracer.span(kind, **attrs):
                yield
        except BaseException as exc:
            self.recorder.record(kind, error=repr(exc), **attrs)
            self.dump_on_error(exc)
            raise
        self.counters.inc(_PLURAL.get(kind, kind + "s"))
        self.recorder.record(
            kind, wall_ms=round((time.perf_counter() - t0) * 1e3, 3), **attrs)

    def note_layout(self, totals: dict[str, int]) -> None:
        """Fold the backend's monotone layout totals (rebuilds,
        overflow_hits, ...) into counters by delta; one ``rebuild``
        instant event per rebuild delta."""
        if not self.enabled:
            return
        for name, total in totals.items():
            delta = max(0, int(total) - self._layout_last.get(name, 0))
            self._layout_last[name] = int(total)
            if delta == 0:
                continue
            self.counters.inc(name, delta)
            if name == "rebuilds":
                for _ in range(delta):
                    self.tracer.instant("rebuild")

    def dump_on_error(self, exc: BaseException) -> None:
        """One-shot flight-recorder postmortem (nested epochs dump once)."""
        if self._dumped:
            return
        self._dumped = True
        self.recorder.dump(
            header=f"flight recorder postmortem "
                   f"({self.recorder.total} records total): {exc!r}")


# ----------------------------------------------------------- CLI plumbing --
def out_path_or_exit(path: str) -> str:
    """Validate a --trace-out / --log-json destination up front: a missing
    parent directory exits 2 (usage error) before any engine work runs."""
    parent = os.path.dirname(os.path.abspath(path))
    if not os.path.isdir(parent):
        print(f"error: output parent directory does not exist: {parent}",
              file=sys.stderr)
        raise SystemExit(2)
    return path


def _jsonable(v: Any) -> Any:
    import numpy as np
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    return v


def write_log_jsonl(engine, path: str) -> None:
    """JSONL export (--log-json): every span line followed by one final
    ``metrics_snapshot`` line — the machine-readable twin of --trace-out."""
    import json
    lines = engine.obs.tracer.jsonl_lines()
    lines.append(json.dumps(
        {"kind": "metrics_snapshot", **_jsonable(engine.metrics_snapshot())}))
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
