"""Flight recorder (DESIGN.md §10.3): a bounded ring buffer of recent
epoch records for postmortems.

Every dispatched epoch appends one small host-side dict (kind, wall
time, batch size, whatever the engine attaches); the deque drops the
oldest record past ``capacity`` so a long replay keeps O(capacity)
memory.  On an exception escaping an instrumented epoch the engine dumps
the ring (``EngineObs``), answering "what was the engine doing right
before it died" without any always-on logging.
"""
from __future__ import annotations

import collections
import json
import sys
import time
from typing import Any, TextIO

__all__ = ["FlightRecorder"]


class FlightRecorder:
    def __init__(self, capacity: int = 128):
        if capacity < 1:
            raise ValueError(f"flight recorder capacity must be >= 1; "
                             f"got {capacity}")
        self._buf: collections.deque[dict[str, Any]] = \
            collections.deque(maxlen=capacity)
        self.total = 0   # records ever written (seq of the next record)

    @property
    def capacity(self) -> int:
        return self._buf.maxlen

    def record(self, kind: str, **fields) -> dict[str, Any]:
        rec = {"seq": self.total, "kind": kind,
               "t_s": round(time.perf_counter(), 6), **fields}
        self._buf.append(rec)
        self.total += 1
        return rec

    def records(self) -> list[dict[str, Any]]:
        """Oldest-to-newest surviving records (at most ``capacity``)."""
        return list(self._buf)

    def dump(self, file: TextIO | None = None, header: str = "") -> str:
        """Write the ring as one JSONL block (postmortem output; defaults
        to stderr) and return it."""
        lines = [json.dumps(r, default=str) for r in self._buf]
        text = "\n".join(([f"# {header}"] if header else []) + lines)
        print(text, file=file or sys.stderr)
        return text
