"""Fixed-bucket log2 histograms for the telemetry layer (DESIGN.md §10.6).

The counter registry (§10.1) already gives us lazily-``+``-folded device
values read back in ONE ``snapshot()`` device_get.  Histograms reuse that
machinery verbatim: a histogram is just a counter whose value is an [B]
(or [S, B] per-lane) count vector, and a sample is a one-hot vector added
with the same lazy ``+`` fold.  Nothing here ever reads a device value —
the §2.4 no-host-sync discipline holds by construction.

Bucketing is fixed log2: bucket 0 holds samples < 1, bucket ``i`` (for
``1 <= i < B-1``) holds ``[2^(i-1), 2^i)``, and the last bucket is
open-ended.  With ``NUM_BUCKETS = 24`` the top finite edge is 2^22 ≈ 4.2M,
which covers microsecond latencies up to ~4 s, wave counts, message
volumes, and frontier sizes at paper scale without configuration.

Percentiles are *estimates*: cumulative counts locate the bucket, then we
interpolate linearly inside its ``[lo, hi)`` span.  That is the standard
Prometheus ``histogram_quantile`` semantics, and with log2 buckets the
relative error is bounded by 2x — good enough to rank tails, which is all
a fixed-bucket histogram promises.

Host-side twins (``one_hot_np``/in-place ``fold_np``) exist for samples
that are born on the host (query wall-clock latency); host and device
counts for the same registry name merge transparently in ``snapshot()``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterable, Mapping

import numpy as np

NUM_BUCKETS = 24

# registry-name prefix marking a counter as a histogram count vector;
# summarize()/the exporters key off it
HIST_PREFIX = "hist_"


# ---------------------------------------------------------------------------
# bucket geometry (host; pure python — shared by estimates and exporters)

def bucket_lo(i: int) -> float:
    """Inclusive lower bound of bucket ``i``."""
    return 0.0 if i == 0 else float(2 ** (i - 1))


def bucket_hi(i: int, num_buckets: int = NUM_BUCKETS) -> float:
    """Exclusive upper bound of bucket ``i`` (inf for the last bucket)."""
    return math.inf if i >= num_buckets - 1 else float(2 ** i)


def edges(num_buckets: int = NUM_BUCKETS) -> list[float]:
    """Upper bucket edges, Prometheus ``le`` style (last is +inf)."""
    return [bucket_hi(i, num_buckets) for i in range(num_buckets)]


# ---------------------------------------------------------------------------
# sampling — device

def bucket_index(value):
    """Device bucket index of ``value`` (scalar or vector, any numeric
    dtype).  Traced-safe: pure jnp ops, no host round trip."""
    import jax.numpy as jnp
    v = jnp.asarray(value, jnp.float32)
    # log2 is safe: the where() picks branch 0 for v < 1, and max(v, 1)
    # keeps the unused lane finite so no nan leaks through the select
    idx = jnp.where(
        v >= 1.0,
        jnp.floor(jnp.log2(jnp.maximum(v, 1.0))).astype(jnp.int32) + 1,
        0,
    )
    return jnp.clip(idx, 0, NUM_BUCKETS - 1)


def one_hot(value, num_buckets: int = NUM_BUCKETS):
    """Device one-hot count vector for a sample.  A scalar ``value`` yields
    one sample; a vector [S] yields S samples (one per lane) scattered into
    the same [B] counts — the batched engines' [S] wave/message stats fold
    straight in."""
    import jax.numpy as jnp
    idx = bucket_index(value)
    counts = jnp.zeros(num_buckets, jnp.int32)
    return counts.at[idx.reshape(-1)].add(1)


# ---------------------------------------------------------------------------
# sampling — host

def bucket_index_np(value: float) -> int:
    """Host twin of :func:`bucket_index` for a python/numpy scalar."""
    v = float(value)
    if not v >= 1.0:  # also catches nan
        return 0
    return min(int(math.floor(math.log2(v))) + 1, NUM_BUCKETS - 1)


def one_hot_np(value: float, num_buckets: int = NUM_BUCKETS) -> np.ndarray:
    """Host one-hot count vector (int64) for one sample."""
    counts = np.zeros(num_buckets, np.int64)
    counts[bucket_index_np(value)] = 1
    return counts


def zeros_np(num_buckets: int = NUM_BUCKETS) -> np.ndarray:
    return np.zeros(num_buckets, np.int64)


def fold_np(counts: np.ndarray, value: float) -> None:
    """In-place host fold of one sample (the serving replayer's per-source
    accumulators use this to avoid a fresh one-hot alloc per query)."""
    counts[bucket_index_np(value)] += 1


# ---------------------------------------------------------------------------
# reading — merge / totals / percentile estimates

def merge(*counts: Iterable) -> np.ndarray:
    """Elementwise sum of count vectors (host).  Merging is exact — counts
    are additive — which is why the sharded engine can fold per-partition
    and the serving layer can pool per-source histograms losslessly."""
    acc = None
    for c in counts:
        a = np.asarray(c, np.int64)
        acc = a.copy() if acc is None else acc + a
    if acc is None:
        return zeros_np()
    return acc


def total(counts) -> int:
    """Number of samples in a count vector (or all rows of an [S, B])."""
    return int(np.sum(np.asarray(counts)))


def percentile(counts, q: float) -> float:
    """Estimated q-th percentile (0..100) of a 1-D count vector.  Empty
    histogram -> nan.  Linear interpolation inside the located bucket; the
    open-ended last bucket reports its lower bound (no upper edge to
    interpolate toward)."""
    c = np.asarray(counts, np.float64).reshape(-1)
    n = c.sum()
    if n <= 0:
        return float("nan")
    target = n * (q / 100.0)
    cum = 0.0
    for i, ci in enumerate(c):
        if ci <= 0:
            continue
        if cum + ci >= target:
            lo, hi = bucket_lo(i), bucket_hi(i, c.size)
            if not math.isfinite(hi):
                return lo
            frac = (target - cum) / ci
            return lo + frac * (hi - lo)
        cum += ci
    return bucket_lo(int(np.nonzero(c)[0][-1]))


def summary(counts) -> Dict[str, Any]:
    """Count + p50/p95/p99 estimates for one count vector.  2-D [S, B]
    per-lane histograms report per-row percentile lists plus the pooled
    estimate of the merged rows."""
    a = np.asarray(counts)
    if a.ndim == 2:
        pooled = a.sum(axis=0)
        return {
            "counts": a.tolist(),
            "count": total(a),
            "p50": percentile(pooled, 50.0),
            "p95": percentile(pooled, 95.0),
            "p99": percentile(pooled, 99.0),
            "per_row_p50": [percentile(row, 50.0) for row in a],
            "per_row_p99": [percentile(row, 99.0) for row in a],
        }
    return {
        "counts": a.reshape(-1).tolist(),
        "count": total(a),
        "p50": percentile(a, 50.0),
        "p95": percentile(a, 95.0),
        "p99": percentile(a, 99.0),
    }


def summarize(counters: Mapping[str, Any],
              prefix: str = HIST_PREFIX) -> Dict[str, Dict[str, Any]]:
    """Extract every ``hist_*`` counter from a registry snapshot into
    ``{name-without-prefix: summary}``.  Non-array values under the prefix
    are ignored (defensive: a scalar named ``hist_...`` is not a
    histogram)."""
    out: Dict[str, Dict[str, Any]] = {}
    for key, value in counters.items():
        if not key.startswith(prefix):
            continue
        a = np.asarray(value)
        if a.ndim == 0:
            continue
        out[key[len(prefix):]] = summary(a)
    return out
