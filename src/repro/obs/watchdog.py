"""Stall / divergence watchdog (DESIGN.md §10.8).

A dynamic-SSSP engine has two silent failure modes the flat counters
cannot surface while they are happening: an epoch that *hangs* (a
collective deadlock, a runaway fixpoint loop inside jit — the host is
blocked inside the dispatch and nothing prints), and an epoch that
*diverges* (wave counts or frontier occupancy climbing past anything the
workload should produce — the run finishes, eventually, but the operator
learns nothing until the final report).

The watchdog covers both with host-side sampling only — it never touches
device values, so the §2.4 discipline is untouched:

  * **stall**: ``EngineObs.epoch`` arms the watchdog on entry and disarms
    on exit.  A lazy daemon thread samples the armed region's wall clock;
    past ``stall_timeout_s`` it emits a structured ``watchdog`` record
    through the FlightRecorder, bumps ``watchdog_stalls``, and triggers
    the recorder's existing one-shot stderr dump (§10.3) so the operator
    gets the last-N-epochs postmortem *while the process is still hung*.
    One firing per armed region — a slow-but-progressing run produces one
    warning per offending epoch, not a warning storm.
  * **slow epoch / frontier blowup**: synchronous post-epoch checks of
    the measured wall time against ``max_epoch_wall_s`` and the epoch's
    frontier attribute against ``max_frontier``.
  * **divergence review**: ``review(counters)`` — called from
    ``metrics_snapshot()`` with the snapshot already in hand — checks the
    waves-per-epoch histogram's top occupied bucket against
    ``max_drain_waves``.  Review findings therefore land in the *next*
    snapshot's counters; the FlightRecorder record is immediate.

All thresholds are opt-out by default-off (0 / inf): a default-config
watchdog only watches for multi-second stalls, which is why the gated
benches can run with it armed and assert silence.
"""
from __future__ import annotations

import dataclasses
import math
import sys
import threading
import time
from typing import Any, TYPE_CHECKING

import numpy as np

from repro.obs import hist as hist_mod

if TYPE_CHECKING:  # pragma: no cover
    from repro.obs import EngineObs

__all__ = ["Watchdog", "WatchdogConfig"]


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Thresholds; 0 / inf disables the corresponding check."""
    stall_timeout_s: float = 30.0    # armed epoch older than this -> stall
    max_epoch_wall_s: float = math.inf  # finished epoch slower than this
    max_frontier: int = 0            # ADD-epoch frontier larger than this
    max_drain_waves: int = 0         # waves-hist top bucket lo >= this
    poll_interval_s: float = 0.0     # 0 -> derived from stall_timeout_s


class Watchdog:
    """One instance per :class:`EngineObs`; all state is host-side."""

    def __init__(self, cfg: WatchdogConfig, obs: "EngineObs"):
        self.cfg = cfg
        self.obs = obs
        self.warnings = 0
        # armed region: (token, kind, t0) — written by the engine thread,
        # read by the sampler; tuple swap is atomic under the GIL
        self._armed: tuple[int, str, float] | None = None
        self._token = 0
        self._fired_token = -1
        self._reviewed_waves = False
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ arm/disarm
    def arm(self, kind: str) -> None:
        self._token += 1
        self._armed = (self._token, kind, time.perf_counter())
        if (self._thread is None
                and math.isfinite(self.cfg.stall_timeout_s)
                and self.cfg.stall_timeout_s > 0):
            self._thread = threading.Thread(
                target=self._sample_loop, name="repro-obs-watchdog",
                daemon=True)
            self._thread.start()

    def disarm(self) -> None:
        self._armed = None

    # ------------------------------------------------------ synchronous checks
    def observe(self, kind: str, wall_s: float, attrs: dict) -> None:
        """Post-epoch threshold checks (engine thread, after a successful
        epoch)."""
        if 0 < self.cfg.max_epoch_wall_s < wall_s:
            self._warn("slow_epoch", epoch=kind, wall_s=round(wall_s, 6),
                       limit_s=self.cfg.max_epoch_wall_s)
        frontier = attrs.get("frontier")
        if (frontier is not None and self.cfg.max_frontier > 0
                and frontier > self.cfg.max_frontier):
            self._warn("frontier_blowup", epoch=kind, frontier=int(frontier),
                       limit=self.cfg.max_frontier)

    def review(self, counters: dict[str, Any]) -> None:
        """Divergence review over a counter snapshot (§10.8): flags a
        waves-per-epoch histogram whose top occupied bucket starts at or
        above ``max_drain_waves``.  Fires at most once per watchdog — the
        histogram is cumulative, so the finding would otherwise repeat on
        every later snapshot."""
        if self.cfg.max_drain_waves <= 0 or self._reviewed_waves:
            return
        counts = counters.get(hist_mod.HIST_PREFIX + "waves_per_epoch")
        if counts is None:
            return
        c = np.asarray(counts).reshape(-1)
        nz = np.nonzero(c)[0]
        if nz.size == 0:
            return
        top_lo = hist_mod.bucket_lo(int(nz[-1]))
        if top_lo >= self.cfg.max_drain_waves:
            self._reviewed_waves = True
            self._warn("wave_divergence", top_bucket_lo=top_lo,
                       limit=self.cfg.max_drain_waves)

    # ---------------------------------------------------------------- sampler
    def _sample_loop(self) -> None:
        poll = self.cfg.poll_interval_s
        if poll <= 0:
            poll = min(1.0, self.cfg.stall_timeout_s / 4.0)
        while not self._stop.wait(poll):
            armed = self._armed
            if armed is None:
                continue
            token, kind, t0 = armed
            elapsed = time.perf_counter() - t0
            if elapsed > self.cfg.stall_timeout_s and token != self._fired_token:
                self._fired_token = token
                self._warn("stall", epoch=kind, elapsed_s=round(elapsed, 3),
                           limit_s=self.cfg.stall_timeout_s)
                # the one-shot postmortem (§10.3) — the engine thread is
                # blocked inside the dispatch, so this is the only chance
                # the operator gets to see the last recorded epochs
                self.obs.dump_on_error(
                    TimeoutError(f"watchdog: {kind} armed for "
                                 f"{elapsed:.1f}s"))

    def stop(self) -> None:
        """Tear down the sampler thread (tests / engine close)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    # ----------------------------------------------------------------- output
    def _warn(self, reason: str, **fields) -> None:
        self.warnings += 1
        self.obs.recorder.record("watchdog", reason=reason, **fields)
        self.obs.counters.inc("watchdog_warnings")
        if reason == "stall":
            self.obs.counters.inc("watchdog_stalls")
        detail = " ".join(f"{k}={v}" for k, v in fields.items())
        print(f"[repro.obs.watchdog] {reason}: {detail}",
              file=sys.stderr, flush=True)
