"""Metrics export: Prometheus text, streaming JSONL, live HTTP (§10.7).

One uniform surface for everything the telemetry layer knows how to
read: a ``metrics_snapshot()`` dict (flat counters + histograms +
attribution, see ``StreamEngineBase.metrics_snapshot``) renders to

  * **Prometheus text exposition** — scalars as counters, dimension-tagged
    vectors as labeled series (``{partition="3"}`` / ``{lane="1"}``), and
    ``hist_*`` count vectors as native Prometheus histograms (cumulative
    ``_bucket{le=...}`` series ending in ``+Inf``, plus ``_count``).
  * **streaming JSONL** — one self-describing JSON object per dump
    (monotonic ``seq``, wall-clock ``t_s``, the snapshot), append-only so
    a long-running serve can be tailed.
  * an optional **stdlib ``http.server`` endpoint** serving ``/metrics``
    (Prometheus text) and ``/metrics.json`` for live scraping — a daemon
    thread, port 0 picks a free port, nothing to install.

Everything is pull-from-snapshot: exporting calls ``snapshot_fn`` which
calls ``metrics_snapshot()`` which performs the single §2.4 device_get.
Export frequency therefore *is* the read-back frequency — scraping every
15 s costs one device_get every 15 s and nothing in between.

``parse_prometheus_text`` is the inverse of the text renderer for the
round-trip tests; it is deliberately small (gauge/counter samples with
optional labels), not a general OpenMetrics parser.
"""
from __future__ import annotations

import json
import math
import threading
import time
from typing import Any, Callable, Dict, Iterable, Mapping, Tuple

import numpy as np

from repro.obs import hist as hist_mod

__all__ = [
    "JsonlMetricsWriter",
    "MetricsServer",
    "parse_prometheus_text",
    "prometheus_lines",
    "prometheus_text",
    "write_prometheus",
]

# snapshot keys whose values are scalar metrics at the top level
_TOP_SCALARS = ("epochs", "adds", "dels", "rounds", "messages")


def _fmt(v: float) -> str:
    """Prometheus sample value: integers stay integral, inf -> +Inf."""
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if math.isnan(f):
        return "NaN"
    if f == int(f) and abs(f) < 2 ** 53:
        return str(int(f))
    return repr(f)


def _san(name: str) -> str:
    """Metric-name-safe identifier."""
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


def prometheus_lines(snapshot: Mapping[str, Any],
                     prefix: str = "repro_") -> list[str]:
    """Render a ``metrics_snapshot()`` dict to Prometheus text lines."""
    lines: list[str] = []
    dims: Dict[str, str] = {}
    for dim, named in (snapshot.get("attribution") or {}).items():
        for name in named:
            dims[name] = dim

    def emit(name: str, kind: str, samples: Iterable[Tuple[str, float]],
             help_: str = "") -> None:
        full = prefix + _san(name)
        if help_:
            lines.append(f"# HELP {full} {help_}")
        lines.append(f"# TYPE {full} {kind}")
        for labels, value in samples:
            lines.append(f"{full}{labels} {_fmt(value)}")

    for key in _TOP_SCALARS:
        if key in snapshot and np.ndim(snapshot[key]) == 0:
            emit(key, "counter", [("", float(snapshot[key]))])

    for name, value in sorted((snapshot.get("counters") or {}).items()):
        a = np.asarray(value)
        if name.startswith(hist_mod.HIST_PREFIX) and a.ndim >= 1:
            counts = a.sum(axis=0) if a.ndim == 2 else a
            base = _san(name)
            full = prefix + base
            lines.append(f"# TYPE {full} histogram")
            cum = 0
            for i, ci in enumerate(np.asarray(counts).reshape(-1)):
                cum += int(ci)
                le = _fmt(hist_mod.bucket_hi(i, int(np.size(counts))))
                lines.append(f'{full}_bucket{{le="{le}"}} {cum}')
            lines.append(f"{full}_count {cum}")
            continue
        if a.ndim == 0:
            emit(name, "counter", [("", float(a))])
        else:
            dim = dims.get(name, "index")
            if a.ndim == 1:
                emit(name, "counter",
                     [(f'{{{_san(dim)}="{i}"}}', float(v))
                      for i, v in enumerate(a)])
            # 2-D non-histogram vectors have no natural label scheme; the
            # JSONL export carries them verbatim instead

    spans = snapshot.get("spans") or {}
    for name, count in sorted(spans.items()):
        emit(f"span_{name}_total", "counter", [("", float(count))])

    for hname, summ in sorted((snapshot.get("histograms") or {}).items()):
        for q in ("p50", "p95", "p99"):
            if q in summ:
                emit(f"{hname}_{q}", "gauge", [("", float(summ[q]))])
    return lines


def prometheus_text(snapshot: Mapping[str, Any],
                    prefix: str = "repro_") -> str:
    return "\n".join(prometheus_lines(snapshot, prefix)) + "\n"


def write_prometheus(path: str, snapshot: Mapping[str, Any],
                     prefix: str = "repro_") -> None:
    with open(path, "w") as fh:
        fh.write(prometheus_text(snapshot, prefix))


def parse_prometheus_text(text: str) -> Dict[str, Dict[Tuple[Tuple[str, str],
                                                             ...], float]]:
    """Parse exposition text back into ``{metric: {labelset: value}}``
    where ``labelset`` is a sorted tuple of (label, value) pairs (empty
    tuple for unlabeled samples).  The round-trip oracle for the renderer
    above."""
    out: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name_part, _, value_part = line.rpartition(" ")
        if "{" in name_part:
            name, _, rest = name_part.partition("{")
            label_body = rest.rstrip("}")
            labels = []
            for item in label_body.split(","):
                if not item:
                    continue
                k, _, v = item.partition("=")
                labels.append((k.strip(), v.strip().strip('"')))
            key = tuple(sorted(labels))
        else:
            name, key = name_part, ()
        value_part = value_part.strip()
        if value_part == "+Inf":
            value = math.inf
        elif value_part == "-Inf":
            value = -math.inf
        else:
            value = float(value_part)
        out.setdefault(name, {})[key] = value
    return out


class JsonlMetricsWriter:
    """Append-only JSONL metrics stream: one JSON object per ``dump()``
    with a monotonic ``seq`` and wall-clock ``t_s``.  ``snapshot_fn`` is
    typically ``engine.metrics_snapshot`` — each dump is one device_get."""

    def __init__(self, path: str, snapshot_fn: Callable[[], Mapping[str, Any]]):
        self.path = path
        self.snapshot_fn = snapshot_fn
        self.seq = 0

    def dump(self) -> dict:
        from repro.obs import _jsonable
        rec = {"seq": self.seq, "t_s": time.time(),
               "metrics": _jsonable(dict(self.snapshot_fn()))}
        with open(self.path, "a") as fh:
            fh.write(json.dumps(rec) + "\n")
        self.seq += 1
        return rec


class MetricsServer:
    """Live scrape endpoint on stdlib ``http.server``: ``GET /metrics``
    returns Prometheus text, ``GET /metrics.json`` the JSON snapshot.
    Runs in a daemon thread; ``port=0`` binds a free port (read it back
    from ``.port``).  Intended for examples and long-running serves — the
    snapshot is taken per request, so an idle server costs nothing."""

    def __init__(self, snapshot_fn: Callable[[], Mapping[str, Any]],
                 host: str = "127.0.0.1", port: int = 0,
                 prefix: str = "repro_"):
        import http.server

        outer = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib API name)
                try:
                    if self.path in ("/metrics", "/"):
                        body = prometheus_text(outer.snapshot_fn(),
                                               outer.prefix).encode()
                        ctype = "text/plain; version=0.0.4"
                    elif self.path == "/metrics.json":
                        from repro.obs import _jsonable
                        body = json.dumps(
                            _jsonable(dict(outer.snapshot_fn()))).encode()
                        ctype = "application/json"
                    else:
                        self.send_error(404)
                        return
                except Exception as exc:  # pragma: no cover - defensive
                    self.send_error(500, repr(exc))
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # silence per-request stderr noise
                pass

        self.snapshot_fn = snapshot_fn
        self.prefix = prefix
        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-obs-metrics",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=2.0)
